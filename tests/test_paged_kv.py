"""Paged KV-cache subsystem: page allocator, history-buffer indirection,
paged decode correctness vs. the dense pool, the Pallas paged-attention
kernel vs. its oracle, and OOM-safe engine behaviour."""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.routing import neutral_router_bias
from repro.kernels import ops as kops, ref
from repro.kvcache import history, paged
from repro.kvcache.cache import CompactKVStore
from repro.models import model as M
from repro.serve.engine import ContinuousBatchingEngine

KEY = jax.random.PRNGKey(0)


def _cfg(name="llama2-7b", **over):
    cfg = get_config(name).smoke()
    return dataclasses.replace(cfg, **over) if over else cfg


def _params(cfg):
    return neutral_router_bias(M.init_params(KEY, cfg))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (l,), dtype=np.int32)
            for l in lens]


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------

def test_allocator_free_list_reuse_and_no_aliasing():
    a = paged.PageAllocator(num_pages=8, page_size=4, max_slots=3,
                            slot_entry_capacity=16)
    assert a.ensure(0, 6) and a.ensure(1, 9)      # 2 + 3 pages
    assert a.free_pages == 3
    owned0 = set(a.block_table[0][:2])
    owned1 = set(a.block_table[1][:3])
    assert not owned0 & owned1                    # no cross-slot aliasing
    # eviction returns pages; the next slot reuses exactly those
    released = a.release(0)
    assert released == 2 and a.free_pages == 5
    assert a.ensure(2, 16)                        # 4 pages incl. recycled
    owned2 = set(a.block_table[2][:4])
    assert owned0 <= owned2 | set(a._free)        # recycled, not leaked
    assert not owned2 & owned1
    # block-table round-trip: release everything -> all pages free
    a.release(1)
    a.release(2)
    assert a.free_pages == 8
    assert (a.fill == 0).all() and (a.block_table == 0).all()


def test_allocator_backpressure_and_capacity():
    a = paged.PageAllocator(num_pages=2, page_size=4, max_slots=2,
                            slot_entry_capacity=16)
    assert not a.can_reserve(0, 12)               # 3 pages > pool
    assert a.ensure(0, 8)
    assert not a.ensure(1, 4)                     # free list empty
    a.release(0)
    assert a.ensure(1, 4)


def test_allocator_overflow_guard():
    a = paged.PageAllocator(num_pages=4, page_size=4, max_slots=1,
                            slot_entry_capacity=16)
    assert a.ensure(0, 4)
    with pytest.raises(RuntimeError, match="proactively"):
        a.append(0, 5, 5)


# ---------------------------------------------------------------------------
# History metadata
# ---------------------------------------------------------------------------

def test_next_fresh_layer_intervals():
    fresh = jnp.asarray(np.array([[1, 1], [0, 1], [1, 0], [0, 1]],
                                 np.bool_))
    l1 = np.asarray(history.next_fresh_layer(fresh))
    # column 0: fresh at 0, 2 -> l1 = 2, -, 4, -
    assert l1[0, 0] == 2 and l1[2, 0] == 4
    # column 1: fresh at 0, 1, 3 -> l1 = 1, 3, -, 4
    assert l1[0, 1] == 1 and l1[1, 1] == 3 and l1[3, 1] == 4


def test_effective_positions_exactly_one_entry_per_token():
    """Each token has exactly one valid entry at every layer."""
    cfg = _cfg()
    params = _params(cfg)
    (p,) = _prompts(cfg, [11])
    _, cache, stats = M.prefill(params, {"tokens": jnp.asarray(p[None])}, cfg)
    gates = np.asarray(stats["attn_gate"])[:, 0]
    nA = gates.shape[0]
    store = paged.init_store(cfg, 16, 4)
    alloc = paged.PageAllocator(16, 4, 1, slot_entry_capacity=32 * nA)
    n = paged.prefill_entry_count(gates, 11, paged.reuse_enabled(cfg))
    assert alloc.ensure(0, n)
    store = paged.pack_prefill(store, cache, jnp.asarray(gates),
                               jnp.int32(11),
                               jnp.asarray(alloc.block_table[0]), cfg)
    alloc.append(0, n, nA * 11)
    view = paged.gather_view(store, jnp.asarray(alloc.block_table))
    E = view["pos"].shape[1]
    in_fill = jnp.arange(E)[None] < jnp.asarray(alloc.fill)[:, None]
    for a in range(nA):
        eff = np.asarray(history.effective_positions(
            view["pos"], view["l0"], view["l1"], in_fill, a))[0]
        valid = eff[eff < history.MASKED_POS]
        assert sorted(valid) == list(range(11)), (a, valid)


def test_paged_view_matches_dense_prefill_views():
    """Store + indirection reconstructs every layer's dense KV view."""
    cfg = _cfg()
    params = _params(cfg)
    (p,) = _prompts(cfg, [13])
    _, cache, stats = M.prefill(params, {"tokens": jnp.asarray(p[None])}, cfg)
    gates = np.asarray(stats["attn_gate"])[:, 0]
    nA, T0 = gates.shape[0], 13
    store = paged.init_store(cfg, 32, 8)
    alloc = paged.PageAllocator(32, 8, 1, slot_entry_capacity=64 * nA)
    n = paged.prefill_entry_count(gates, T0, paged.reuse_enabled(cfg))
    assert alloc.ensure(0, n)
    store = paged.pack_prefill(store, cache, jnp.asarray(gates),
                               jnp.int32(T0),
                               jnp.asarray(alloc.block_table[0]), cfg)
    alloc.append(0, n, nA * T0)
    assert alloc.saved_fraction > 0.0

    view = paged.gather_view(store, jnp.asarray(alloc.block_table))
    k_views, _ = paged.prefill_views_from_cache(cache, cfg)
    E = view["pos"].shape[1]
    in_fill = jnp.arange(E)[None] < jnp.asarray(alloc.fill)[:, None]
    for a in range(nA):
        eff = np.asarray(history.effective_positions(
            view["pos"], view["l0"], view["l1"], in_fill, a))[0]
        sel = eff < history.MASKED_POS
        got = np.zeros((T0,) + k_views.shape[2:], np.float32)
        got[eff[sel]] = np.asarray(view["k"][0], np.float32)[sel]
        np.testing.assert_allclose(got, np.asarray(k_views[a],
                                                   np.float32)[:T0],
                                   rtol=1e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# Paged decode == dense decode (model level) + CompactKVStore accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernels", [False, True])
def test_paged_decode_matches_dense_and_compact_store(use_kernels):
    cfg = _cfg(use_kernels=use_kernels) if use_kernels else _cfg()
    params = _params(cfg)
    nA = len(cfg.attention_layers)
    max_len, lens = 32, [10, 6]
    prompts = _prompts(cfg, lens)

    from repro.serve.engine import init_pool, pool_insert
    pool = init_pool(cfg, 2, max_len)
    store = paged.init_store(cfg, 64, 8)
    alloc = paged.PageAllocator(64, 8, 2, slot_entry_capacity=max_len * nA)
    comp = CompactKVStore(nA, cfg.num_kv_heads, cfg.resolved_head_dim)
    zero = np.zeros((cfg.num_kv_heads, cfg.resolved_head_dim), np.float32)
    toks = []
    for i, p in enumerate(prompts):
        lg, c, st = M.prefill(params, {"tokens": jnp.asarray(p[None])}, cfg,
                              pad_to=max_len)
        pool = pool_insert(pool, c, i, cfg)
        g = np.asarray(st["attn_gate"])[:, 0]
        n = paged.prefill_entry_count(g, lens[i], paged.reuse_enabled(cfg))
        assert alloc.ensure(i, n + nA)
        store = paged.pack_prefill(store, c, jnp.asarray(g),
                                   jnp.int32(lens[i]),
                                   jnp.asarray(alloc.block_table[i]), cfg)
        alloc.append(i, n, nA * lens[i])
        for t_idx in range(lens[i]):
            for a in range(nA):
                comp.append(a, zero, zero, executed=bool(g[a, t_idx] > 0.5))
        toks.append(int(jnp.argmax(lg[0])))

    dec = jax.jit(partial(M.decode_step, cfg=cfg))
    pdec = jax.jit(partial(M.paged_decode_step, cfg=cfg))
    t = np.array(lens, np.int32)
    tok = np.array(toks, np.int32)
    for step in range(5):
        lg_d, pool, _ = dec(params, pool,
                            {"tokens": jnp.asarray(tok[:, None])},
                            jnp.asarray(t))
        for s in range(2):
            assert alloc.ensure(s, int(alloc.fill[s]) + nA)
        lg_p, store, sp = pdec(params, store,
                               {"tokens": jnp.asarray(tok[:, None])},
                               jnp.asarray(t), jnp.asarray(alloc.block_table),
                               jnp.asarray(alloc.fill))
        g = np.asarray(sp["attn_gate"])
        for s in range(2):
            alloc.append(s, int(1 + (g[1:, s] > 0.5).sum()), nA)
            for a in range(nA):
                comp.append(a, zero, zero, executed=bool(g[a, s] > 0.5))
        assert (np.asarray(jnp.argmax(lg_p, -1))
                == np.asarray(jnp.argmax(lg_d, -1))).all(), step
        np.testing.assert_allclose(np.asarray(lg_p, np.float32),
                                   np.asarray(lg_d, np.float32),
                                   rtol=2e-2, atol=2e-2)
        tok = np.asarray(jnp.argmax(lg_d, -1), np.int32)
        t = t + 1

    # the live history-buffer measurement equals the CompactKVStore
    # accounting replayed over the same gate log
    assert comp.stats.saved_fraction > 0.0
    assert abs(alloc.saved_fraction - comp.stats.saved_fraction) < 1e-9


# ---------------------------------------------------------------------------
# Pallas kernel vs oracle
# ---------------------------------------------------------------------------

def test_paged_attention_kernel_matches_ref():
    rng = np.random.default_rng(0)
    B, Hq, Hkv, dh = 3, 4, 2, 32
    P, ps, J = 16, 4, 3
    E = J * ps
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, dh)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((P, ps, Hkv, dh)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((P, ps, Hkv, dh)), jnp.float32)
    kt = jnp.asarray(rng.standard_normal((B, 1, Hkv, dh)), jnp.float32)
    vt = jnp.asarray(rng.standard_normal((B, 1, Hkv, dh)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, P, (B, J)), jnp.int32)
    pos = rng.integers(0, 9, (B, E)).astype(np.int32)
    pos[rng.random((B, E)) < 0.4] = history.MASKED_POS
    qpos = jnp.asarray(np.full((B, 1), 9, np.int32))
    o_k = kops.paged_decode_attention(q, kp, vp, bt, jnp.asarray(pos),
                                      kt, vt, q_positions=qpos)
    o_r = ref.paged_attention_ref(q, kp, vp, bt, jnp.asarray(pos),
                                  kt, vt, q_positions=qpos)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_kernel_empty_history():
    """A fresh slot (no committed entries) degrades to self-attention."""
    rng = np.random.default_rng(1)
    B, Hq, Hkv, dh = 2, 2, 1, 16
    P, ps, J = 4, 4, 2
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, dh)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((P, ps, Hkv, dh)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((P, ps, Hkv, dh)), jnp.float32)
    kt = jnp.asarray(rng.standard_normal((B, 1, Hkv, dh)), jnp.float32)
    vt = jnp.asarray(rng.standard_normal((B, 1, Hkv, dh)), jnp.float32)
    bt = jnp.zeros((B, J), jnp.int32)
    pos = jnp.full((B, J * ps), history.MASKED_POS, jnp.int32)
    qpos = jnp.zeros((B, 1), jnp.int32)
    o_k = kops.paged_decode_attention(q, kp, vp, bt, pos, kt, vt,
                                      q_positions=qpos)
    o_r = ref.paged_attention_ref(q, kp, vp, bt, pos, kt, vt,
                                  q_positions=qpos)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Engine: paged mode
# ---------------------------------------------------------------------------

def test_paged_engine_token_identity_mixed_lengths():
    cfg = _cfg()
    params = _params(cfg)
    prompts = _prompts(cfg, [9, 16, 5, 21])
    dense = ContinuousBatchingEngine(cfg, params, max_slots=2, max_len=48)
    ud = [dense.submit(p, max_new_tokens=5) for p in prompts]
    outd = dense.run()
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_len=48,
                                   kv_mode="paged", page_size=8)
    up = [eng.submit(p, max_new_tokens=5) for p in prompts]
    outp = eng.run()
    for a, b in zip(ud, up):
        np.testing.assert_array_equal(outd["results"][a].tokens,
                                      outp["results"][b].tokens)
    s = outp["stats"]
    assert s.kv_mode == "paged"
    assert s.requests_completed == 4
    assert s.history_hit_rate > 0.0
    assert len(s.history_hits_per_layer) == len(cfg.attention_layers)
    assert s.history_hits_per_layer[0] == 0.0          # dense base layer
    assert 0.0 < s.kv_entries_saved_fraction < 0.5
    assert 0 < s.pages_peak <= s.pages_total
    # full release on eviction: every page back on the free list
    assert eng.allocator.free_pages == eng.num_pages
    assert (eng.allocator.fill == 0).all()


def test_paged_engine_preemption_under_page_pressure():
    """A pool too small for both residents forces a mid-decode preemption;
    the preempted request re-prefills and tokens stay identical (greedy)."""
    cfg = _cfg()
    params = _params(cfg)
    prompts = _prompts(cfg, [8, 8], seed=1)
    dense = ContinuousBatchingEngine(cfg, params, max_slots=2, max_len=48)
    ud = [dense.submit(p, max_new_tokens=16) for p in prompts]
    outd = dense.run()
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_len=48,
                                   kv_mode="paged", page_size=8,
                                   num_pages=6)
    up = [eng.submit(p, max_new_tokens=16) for p in prompts]
    outp = eng.run()
    assert outp["stats"].preemptions >= 1
    assert outp["stats"].requests_completed == 2
    for a, b in zip(ud, up):
        np.testing.assert_array_equal(outd["results"][a].tokens,
                                      outp["results"][b].tokens)


def test_paged_engine_rejects_unservable_request():
    cfg = _cfg()
    params = _params(cfg)
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_len=48,
                                   kv_mode="paged", page_size=8,
                                   num_pages=6)
    with pytest.raises(ValueError, match="worst-case KV"):
        eng.submit(_prompts(cfg, [40])[0], max_new_tokens=8)


def test_paged_engine_submit_bound_covers_admission_gate():
    """Livelock regression: with max_new_tokens=1 the lifetime worst case
    is prompt_len·nA, one step below the admission gate's (prompt_len+1)·nA
    — submit must reject rather than accept a request that _can_place can
    never pass (run() would otherwise spin forever)."""
    cfg = _cfg()                                  # nA = 2
    params = _params(cfg)
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_len=48,
                                   kv_mode="paged", page_size=8,
                                   num_pages=2)   # exactly 8·2 = prompt·nA
    with pytest.raises(ValueError, match="worst-case KV"):
        eng.submit(_prompts(cfg, [8])[0], max_new_tokens=1)
    # one page smaller than the gate's requirement still fits fine
    eng2 = ContinuousBatchingEngine(cfg, params, max_slots=1, max_len=48,
                                    kv_mode="paged", page_size=8,
                                    num_pages=3)
    uid = eng2.submit(_prompts(cfg, [8])[0], max_new_tokens=1)
    out = eng2.run()
    assert out["results"][uid].finish_reason == "length"


def test_paged_engine_max_len_boundary_all_fresh():
    """Worst storage case: warm-start router (keeps everything => every
    entry fresh at every layer) with the longest admissible prompt
    (max_len - 1).  The per-slot block table must hold it and the run must
    finish by max_len without tripping the headroom loop."""
    cfg = _cfg()
    params = M.init_params(KEY, cfg)          # warm-start bias: no skipping
    max_len = 16
    eng = ContinuousBatchingEngine(cfg, params, max_slots=1,
                                   max_len=max_len, kv_mode="paged",
                                   page_size=8)
    uid = eng.submit(_prompts(cfg, [max_len - 1])[0], max_new_tokens=8)
    out = eng.run()
    r = out["results"][uid]
    assert r.finish_reason == "max_len"
    assert out["stats"].kv_entries_saved_fraction == 0.0   # all fresh
    assert eng.allocator.free_pages == eng.num_pages


def test_paged_engine_rejects_unpageable_config():
    cfg = get_config("gemma3-12b").smoke()       # local ring layers
    params = M.init_params(KEY, cfg)
    with pytest.raises(ValueError, match="paged KV"):
        ContinuousBatchingEngine(cfg, params, max_slots=1, max_len=32,
                                 kv_mode="paged")
    assert not paged.can_page(cfg)
    g = _cfg()
    g = dataclasses.replace(g, skip=dataclasses.replace(g.skip,
                                                        mode="gather"))
    assert not paged.can_page(g)
    assert paged.can_page(_cfg())
