"""Prefix caching with copy-on-write pages + quantized paged KV
(``kvcache/prefix.py``, the refcounted ``PageAllocator``, the quantized
page payloads in ``kernels/paged_attention.py``) and the redesigned
``EngineConfig`` / streaming serve surface.

The load-bearing invariants:

* refcount conservation — every page is free with refcount 0 or held
  with refcount == chain memberships + record pins, across alias / COW /
  release / preemption / spec rollback / deadline expiry / kill→resume;
* warm-prefix admission is *invisible* in fp16: a prompt served through
  a shared prefix decodes bit-identically to a cold run;
* quantized pages (int8/int4, pow2 per-(entry, head) scales) match the
  dense oracle within tolerance and cut peak bytes;
* the EngineConfig shim: flat legacy kwargs behave exactly like the
  grouped config (one DeprecationWarning), invalid combinations raise
  ConfigError;
* ``submit()`` handles stream ``(token, step)`` pairs exactly once, in
  order, and ``result()``/``done()`` agree with ``run()``.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.routing import neutral_router_bias
from repro.kernels import ops as kops, ref
from repro.kvcache import history, paged
from repro.kvcache.prefix import PrefixCache
from repro.models import model as M
from repro.serve import (ConfigError, ContinuousBatchingEngine, EngineConfig,
                         KVConfig, RobustnessConfig, SchedulingConfig,
                         SpecConfig)
from repro.serve.faults import Fault, as_fault_plan
from repro.serve.errors import SimulatedKill

KEY = jax.random.PRNGKey(0)


def _cfg(name="llama2-7b", **over):
    cfg = get_config(name).smoke()
    return dataclasses.replace(cfg, **over) if over else cfg


def _params(cfg, neutral=True):
    p = M.init_params(KEY, cfg)
    return neutral_router_bias(p) if neutral else p


def _engine(cfg, params, *, prefix=True, page_size=8, prefix_block=8,
            max_slots=2, max_len=48, num_pages=None, kv_dtype=None,
            spec_k=0, robustness=None):
    return ContinuousBatchingEngine(cfg, params, config=EngineConfig(
        kv=KVConfig(kv_mode="paged", page_size=page_size,
                    prefix_cache=prefix, prefix_block=prefix_block,
                    num_pages=num_pages, kv_dtype=kv_dtype),
        scheduling=SchedulingConfig(max_slots=max_slots, max_len=max_len),
        spec=SpecConfig(spec_k=spec_k),
        robustness=robustness or RobustnessConfig()))


def _shared_prompts(cfg, prefix_len=24, tails=(4, 6), seed=7):
    """Prompts sharing a ``prefix_len``-token prefix with fresh tails."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, (prefix_len,), dtype=np.int32)
    return [np.concatenate(
        [prefix, rng.integers(0, cfg.vocab_size, (t,), dtype=np.int32)])
        for t in tails]


def _no_leaks(eng):
    """Free pages + record-pinned pages must tile the pool exactly."""
    eng.allocator.check_conservation(
        eng.prefix.page_pins() if eng.prefix is not None else None)
    pinned = set()
    if eng.prefix is not None:
        pinned = set(eng.prefix.page_pins())
    assert eng.allocator.free_pages == eng.num_pages - len(pinned)


# ---------------------------------------------------------------------------
# Allocator refcounts: alias / COW / release conservation
# ---------------------------------------------------------------------------

def test_refcount_alias_release_conservation():
    a = paged.PageAllocator(num_pages=8, page_size=4, max_slots=3,
                            slot_entry_capacity=32)
    assert a.ensure(0, 10)                       # 3 private pages
    shared = list(a.chain(0)[:2])                # pretend first 2 published
    a.ref_pages(shared)                          # record pin
    pins = {p: 1 for p in shared}
    a.check_conservation(pins)
    # warm admission aliases the shared pages into a fresh slot
    a.alias_into(1, shared)
    assert all(a.refcount[p] == 3 for p in shared)   # chain0 + pin + chain1
    assert a.ensure(1, 12)                       # private COW/suffix page
    a.seed_fill(1, 8)
    a.check_conservation(pins)
    # releasing the donor keeps shared pages resident (record + slot 1)
    a.release(0)
    assert all(a.refcount[p] == 2 for p in shared)
    a.check_conservation(pins)
    # releasing the aliasing slot leaves only the record pins
    a.release(1)
    assert all(a.refcount[p] == 1 for p in shared)
    a.check_conservation(pins)
    assert a.free_pages == a.num_pages - len(shared)
    # dropping the record frees everything — full conservation round trip
    assert a.deref_pages(shared) == len(shared)
    a.check_conservation()
    assert a.free_pages == a.num_pages


def test_trim_never_reclaims_shared_pages():
    a = paged.PageAllocator(num_pages=8, page_size=4, max_slots=2,
                            slot_entry_capacity=32)
    assert a.ensure(0, 8)
    shared = list(a.chain(0)[:2])
    a.ref_pages(shared)
    a.release(0)
    a.alias_into(1, shared)
    assert a.ensure(1, 16)                       # spec window over-reserve
    a.seed_fill(1, 8)                            # only the prefix committed
    # rollback trims the unused tail; the shared pages must stay put
    assert a.trim(1) == 2
    assert list(a.chain(1)) == shared
    assert all(a.refcount[p] == 2 for p in shared)
    a.check_conservation({p: 1 for p in shared})


def test_prefix_publish_lookup_lru_and_clear():
    a = paged.PageAllocator(num_pages=16, page_size=4, max_slots=2,
                            slot_entry_capacity=64)
    pc = PrefixCache(a, block=4, reuse=False)
    toks = np.arange(100, 112, dtype=np.int32)   # 12 tokens
    nA = 2
    gates = np.ones((nA, 12), np.float32)        # reuse off: 2 entries/token
    assert a.ensure(0, 12 * nA)
    chain = a.chain(0)
    assert pc.publish(toks, gates, chain) == 3   # boundaries 4, 8, 12
    a.release(0)
    a.check_conservation(pc.page_pins())
    # longest strict prefix wins; an exact-length prompt matches len-1 cap
    rec = pc.lookup(toks)
    assert rec.length == 8 and rec.entries == 16
    assert pc.lookup(np.arange(100, 117, dtype=np.int32)[:13]).length == 12
    assert pc.lookup(np.arange(50, 62, dtype=np.int32)) is None
    assert (pc.hits, pc.misses) == (2, 1)
    # LRU eviction prefers the longest at equal stamp; pinned never goes
    long_rec = pc.lookup(np.arange(100, 113, dtype=np.int32))
    pc.pin(long_rec)
    freed_pages = [pc.evict_one() for _ in range(2)]
    assert all(f is not None for f in freed_pages)
    assert pc.lookup(np.arange(100, 113, dtype=np.int32)) is long_rec
    pc.unpin(long_rec)
    pc.clear()
    a.check_conservation()
    assert a.free_pages == a.num_pages and len(pc) == 0


def test_copy_page_masked_blanks_divergent_tail():
    cfg = _cfg()
    store = paged.init_store(cfg, num_pages=4, page_size=8)
    ps = 8
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.standard_normal(store["k_pages"].shape[1:]),
                    store["k_pages"].dtype)
    store["k_pages"] = store["k_pages"].at[1].set(k)
    store["pos_pages"] = store["pos_pages"].at[1].set(
        jnp.arange(ps, dtype=jnp.int32))
    out = paged.copy_page_masked(store, jnp.int32(1), jnp.int32(3),
                                 jnp.int32(5))
    np.testing.assert_array_equal(np.asarray(out["k_pages"][3][:5]),
                                  np.asarray(k[:5]))
    assert (np.asarray(out["k_pages"][3][5:]) == 0).all()
    assert (np.asarray(out["pos_pages"][3][5:]) == history.MASKED_POS).all()
    assert (np.asarray(out["pos_pages"][3][:5]) == np.arange(5)).all()


# ---------------------------------------------------------------------------
# Quantized pages: pow2 scales, kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", ["int8", "int4"])
def test_quantize_roundtrip_pow2_bounded_error(kv_dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((6, 3, 16)) * 3.0, jnp.float32)
    kc, vc, ks, vs = paged.quantize_entries(x, x, kv_dtype)
    assert kc.dtype == jnp.int8
    # scales are exact powers of two (BFP shift-dequant idiom)
    exps = np.log2(np.asarray(ks))
    np.testing.assert_array_equal(exps, np.round(exps))
    dq = np.asarray(paged.dequantize_entries(kc, ks, kv_dtype))
    # rounding error is bounded by half a step per element
    assert np.max(np.abs(dq - np.asarray(x))) <= np.max(np.asarray(ks)) / 2
    rel = np.abs(dq - np.asarray(x)).max() / np.abs(np.asarray(x)).max()
    assert rel < (0.02 if kv_dtype == "int8" else 0.2)


@pytest.mark.parametrize("kv_dtype", ["int8", "int4"])
def test_quantized_pages_kernel_matches_oracle(kv_dtype):
    rng = np.random.default_rng(2)
    B, Hq, Hkv, dh = 3, 4, 2, 32
    P, ps, J = 16, 4, 3
    E = J * ps
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, dh)), jnp.float32)
    kf = jnp.asarray(rng.standard_normal((P, ps, Hkv, dh)), jnp.float32)
    vf = jnp.asarray(rng.standard_normal((P, ps, Hkv, dh)), jnp.float32)
    kt = jnp.asarray(rng.standard_normal((B, 1, Hkv, dh)), jnp.float32)
    vt = jnp.asarray(rng.standard_normal((B, 1, Hkv, dh)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, P, (B, J)), jnp.int32)
    pos = rng.integers(0, 9, (B, E)).astype(np.int32)
    pos[rng.random((B, E)) < 0.4] = history.MASKED_POS
    qpos = jnp.asarray(np.full((B, 1), 9, np.int32))
    kp, vp, ksc, vsc = paged.quantize_entries(kf, vf, kv_dtype)
    o_k = kops.paged_decode_attention(
        q, kp, vp, bt, jnp.asarray(pos), kt, vt, q_positions=qpos,
        k_scales=ksc, v_scales=vsc, kv_dtype=kv_dtype)
    # oracle 1: the ref dequantizes the same codes up front
    o_r = ref.paged_attention_ref(
        q, kp, vp, bt, jnp.asarray(pos), kt, vt, q_positions=qpos,
        k_scales=ksc, v_scales=vsc, kv_dtype=kv_dtype)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)
    # oracle 2: fp32 ref over explicitly dequantized pools — proves the
    # in-walk dequant is the plain quantization error, nothing kernel-shaped
    o_f = ref.paged_attention_ref(
        q, paged.dequantize_entries(kp, ksc, kv_dtype),
        paged.dequantize_entries(vp, vsc, kv_dtype),
        bt, jnp.asarray(pos), kt, vt, q_positions=qpos)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_f),
                               rtol=2e-5, atol=2e-5)


def test_entry_bytes_int8_cut_at_least_40pct():
    cfg = _cfg()
    fp16 = paged.entry_bytes(cfg)
    assert paged.entry_bytes(cfg, "int8") <= 0.6 * fp16
    assert paged.entry_bytes(cfg, "int4") < paged.entry_bytes(cfg, "int8")


# ---------------------------------------------------------------------------
# Engine: warm-prefix admission
# ---------------------------------------------------------------------------

def test_warm_prefix_bit_identical_and_conserved():
    cfg = _cfg()
    params = _params(cfg)
    p1, p2 = _shared_prompts(cfg, prefix_len=24, tails=(4, 6))

    cold = _engine(cfg, params, prefix=False)
    hc = cold.submit(p2, max_new_tokens=8)
    want = cold.run()["results"][int(hc)].tokens

    eng = _engine(cfg, params)
    eng.submit(p1, max_new_tokens=4)
    out1 = eng.run()
    assert out1["stats"].prefix_hits == 0 and len(eng.prefix) > 0
    h2 = eng.submit(p2, max_new_tokens=8)
    out2 = eng.run()
    s = out2["stats"]
    assert s.prefix_hits == 1 and s.prefix_tokens_saved == 24
    np.testing.assert_array_equal(out2["results"][int(h2)].tokens, want)
    _no_leaks(eng)
    # the warm run republished the longer prefix — a third request rides it
    h3 = eng.submit(np.concatenate([p2, p2[:3]]), max_new_tokens=4)
    out3 = eng.run()
    assert out3["stats"].prefix_hits == 1
    assert out3["results"][int(h3)].finish_reason == "length"
    _no_leaks(eng)


def test_warm_prefix_cow_boundary_page_identity():
    """A record whose entry count straddles a page forces the COW copy
    (plain params: every gate fires, so entries are exactly 2/token —
    block 2 with page 16 lands records mid-page)."""
    cfg = _cfg()
    params = _params(cfg, neutral=False)
    p1, p2 = _shared_prompts(cfg, prefix_len=10, tails=(2, 4), seed=3)

    cold = _engine(cfg, params, prefix=False, page_size=16)
    hc = cold.submit(p2, max_new_tokens=6)
    want = cold.run()["results"][int(hc)].tokens

    eng = _engine(cfg, params, page_size=16, prefix_block=2)
    eng.submit(p1, max_new_tokens=2)
    eng.run()
    rec = eng.prefix.lookup(p2)
    assert rec is not None and rec.entries % 16 != 0, \
        "test geometry must exercise the COW partial-boundary page"
    h2 = eng.submit(p2, max_new_tokens=6)
    out = eng.run()
    assert out["stats"].prefix_hits == 1
    np.testing.assert_array_equal(out["results"][int(h2)].tokens, want)
    _no_leaks(eng)


@pytest.mark.parametrize("kv_dtype", ["int8", "int4"])
def test_warm_prefix_quantized_within_tolerance(kv_dtype):
    """Quantized pages make warm restore lossy relative to the cold
    fp-precision prefill context, so identity is behavioural, not
    bitwise: the engine must complete, conserve pages, and (int8) stay
    on the cold-run token path."""
    cfg = _cfg()
    params = _params(cfg)
    p1, p2 = _shared_prompts(cfg, prefix_len=24, tails=(4, 6))

    cold = _engine(cfg, params, prefix=False, kv_dtype=kv_dtype)
    hc = cold.submit(p2, max_new_tokens=8)
    want = np.asarray(cold.run()["results"][int(hc)].tokens)

    eng = _engine(cfg, params, kv_dtype=kv_dtype)
    eng.submit(p1, max_new_tokens=4)
    eng.run()
    h2 = eng.submit(p2, max_new_tokens=8)
    out = eng.run()
    assert out["stats"].prefix_hits == 1
    got = np.asarray(out["results"][int(h2)].tokens)
    assert got.shape == want.shape
    if kv_dtype == "int8":
        assert float(np.mean(got == want)) >= 0.75, (got, want)
    _no_leaks(eng)


def test_warm_prefix_survives_preemption_pressure():
    """A page pool too small for everyone: preemptions + record evictions
    must conserve refcounts and keep every token identical to an
    uncontended cold engine."""
    cfg = _cfg()
    params = _params(cfg)
    prompts = _shared_prompts(cfg, prefix_len=16, tails=(4, 6, 8, 2),
                              seed=11)

    roomy = _engine(cfg, params, prefix=False, max_slots=4, max_len=48)
    hr = [roomy.submit(p, max_new_tokens=6) for p in prompts]
    outr = roomy.run()
    want = {int(h): outr["results"][int(h)].tokens for h in hr}

    # nA * max_len = one slot's worst case; 3 slots' worth for 4 requests
    tight_pages = 3 * (48 * 2) // 8
    eng = _engine(cfg, params, max_slots=4, max_len=48,
                  num_pages=tight_pages)
    hs = [eng.submit(p, max_new_tokens=6) for p in prompts]
    out = eng.run()
    for h, r in zip(hr, hs):
        np.testing.assert_array_equal(out["results"][int(r)].tokens,
                                      want[int(h)])
    _no_leaks(eng)


def test_warm_prefix_spec_rollback_conserved():
    """Speculative decoding over warm admissions: draft windows
    over-reserve and roll back against chains holding aliased pages —
    trim must return only private tail pages, and tokens must match the
    non-speculative warm engine exactly (temperature 0)."""
    cfg = _cfg()
    params = _params(cfg)
    p1, p2 = _shared_prompts(cfg, prefix_len=16, tails=(4, 6), seed=5)

    plain = _engine(cfg, params, max_len=64)
    plain.submit(p1, max_new_tokens=4)
    plain.run()
    hp = plain.submit(p2, max_new_tokens=10)
    outp = plain.run()
    assert outp["stats"].prefix_hits == 1
    want = outp["results"][int(hp)].tokens

    spec = _engine(cfg, params, max_len=64, spec_k=3)
    spec.submit(p1, max_new_tokens=4)
    spec.run()
    hs = spec.submit(p2, max_new_tokens=10)
    outs = spec.run()
    s = outs["stats"]
    assert s.prefix_hits == 1 and s.spec_windows > 0
    np.testing.assert_array_equal(outs["results"][int(hs)].tokens, want)
    _no_leaks(spec)


def test_deadline_expiry_releases_warm_pins():
    cfg = _cfg()
    params = _params(cfg)
    p1, p2 = _shared_prompts(cfg, prefix_len=24, tails=(4, 6), seed=9)
    eng = _engine(cfg, params)
    eng.submit(p1, max_new_tokens=4)
    eng.run()
    # expired before admission: the probe's pins/aliases must unwind
    h = eng.submit(p2, max_new_tokens=8, deadline_s=0.0)
    out = eng.run()
    assert out["results"][int(h)].finish_reason == "deadline"
    assert not eng._warm_pending
    _no_leaks(eng)
    # and the cache still serves the next warm admission normally
    h2 = eng.submit(p2, max_new_tokens=4)
    out2 = eng.run()
    assert out2["stats"].prefix_hits >= 1
    assert out2["results"][int(h2)].finish_reason == "length"
    _no_leaks(eng)


def test_kill_resume_with_prefix_cache(tmp_path):
    """A SimulatedKill mid-run, resumed by a fresh engine: tokens must be
    bit-identical to a clean run, the restored allocator must conserve
    (records are NOT serialized — resume drops them), and publishing
    must work again after resume."""
    cfg = _cfg()
    params = _params(cfg)
    prompts = _shared_prompts(cfg, prefix_len=16, tails=(4, 6, 8), seed=13)

    clean = _engine(cfg, params, max_slots=3)
    hc = [clean.submit(p, max_new_tokens=6) for p in prompts]
    outc = clean.run()
    want = [outc["results"][int(h)].tokens for h in hc]

    snap_dir = str(tmp_path / "snaps")
    eng = _engine(cfg, params, max_slots=3,
                  robustness=RobustnessConfig(
                      snapshot_dir=snap_dir,
                      faults=as_fault_plan([
                          Fault("kill", step=6, message="yank")])))
    uids = [eng.submit(p, max_new_tokens=6) for p in prompts]
    with pytest.raises(SimulatedKill, match="yank"):
        eng.run()

    eng2 = _engine(cfg, params, max_slots=3,
                   robustness=RobustnessConfig(snapshot_dir=snap_dir))
    assert eng2.resume() >= 1
    out = eng2.run()
    assert sorted(out["results"]) == sorted(int(u) for u in uids)
    for u, w in zip(uids, want):
        np.testing.assert_array_equal(out["results"][int(u)].tokens, w)
    _no_leaks(eng2)
    # records died with the killed process (they are not serialized);
    # the cache itself still works: a cold publish, then a warm hit
    h = eng2.submit(prompts[0], max_new_tokens=4)
    out2 = eng2.run()
    assert out2["results"][int(h)].finish_reason == "length"
    assert out2["stats"].prefix_hits == 0
    h2 = eng2.submit(prompts[1], max_new_tokens=4)
    out3 = eng2.run()
    assert out3["stats"].prefix_hits == 1
    assert out3["results"][int(h2)].finish_reason == "length"
    _no_leaks(eng2)


# ---------------------------------------------------------------------------
# EngineConfig shim + streaming surface
# ---------------------------------------------------------------------------

def test_engine_config_shim_equivalence():
    cfg = _cfg()
    params = _params(cfg)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, (l,), dtype=np.int32)
               for l in (12, 20)]

    import repro.serve.engine as engine_mod
    engine_mod._legacy_warned = False     # once-per-process latch
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        legacy = ContinuousBatchingEngine(
            cfg, params, max_slots=2, max_len=48, kv_mode="paged",
            page_size=8)
        dep = [w for w in caught if issubclass(w.category,
                                               DeprecationWarning)]
        assert len(dep) == 1 and "docs/serving.md" in str(dep[0].message)
    grouped = _engine(cfg, params, prefix=False)
    hl = [legacy.submit(p, max_new_tokens=6) for p in prompts]
    hg = [grouped.submit(p, max_new_tokens=6) for p in prompts]
    ol, og = legacy.run(), grouped.run()
    for a, b in zip(hl, hg):
        np.testing.assert_array_equal(ol["results"][int(a)].tokens,
                                      og["results"][int(b)].tokens)

    with pytest.raises(ConfigError, match="either"):
        ContinuousBatchingEngine(cfg, params, max_slots=2,
                                 config=EngineConfig())
    with pytest.raises(TypeError):
        ContinuousBatchingEngine(cfg, params, not_a_kwarg=1)


def test_engine_config_validation_errors():
    # validation lives in EngineConfig.__post_init__: a bad combination
    # never even becomes a config object, so the engine can trust any
    # EngineConfig it is handed
    for make in (
            lambda: EngineConfig(kv=KVConfig(kv_mode="paged",
                                             kv_dtype="fp8")),
            lambda: EngineConfig(kv=KVConfig(kv_mode="dense",
                                             kv_dtype="int8")),
            lambda: EngineConfig(kv=KVConfig(kv_mode="dense",
                                             prefix_cache=True)),
            lambda: EngineConfig(kv=KVConfig(kv_mode="paged",
                                             prefix_cache=True,
                                             prefix_block=0)),
            lambda: EngineConfig(kv=KVConfig(kv_mode="paged", page_size=0)),
            lambda: EngineConfig(scheduling=SchedulingConfig(max_slots=0)),
            lambda: EngineConfig(spec=SpecConfig(spec_k=-1)),
    ):
        with pytest.raises(ConfigError):
            make()
    # ConfigError is a ValueError: existing callers' try/except still work
    assert issubclass(ConfigError, ValueError)


def test_streaming_handle_tokens_exactly_once():
    cfg = _cfg()
    params = _params(cfg)
    rng = np.random.default_rng(2)
    p = rng.integers(0, cfg.vocab_size, (12,), dtype=np.int32)
    eng = _engine(cfg, params, prefix=False)
    h = eng.submit(p, max_new_tokens=6)
    assert not h.done()
    pairs = list(h.tokens())
    assert h.done()
    res = h.result()
    assert res.finish_reason == "length"
    # in order, exactly once, and exactly the run()-visible tokens
    np.testing.assert_array_equal([t for t, _ in pairs], res.tokens)
    steps = [s for _, s in pairs]
    assert steps == sorted(steps)
    # each pair is yielded exactly once per iterator; a fresh iterator
    # replays the identical stream, and result() stays stable
    assert list(h.tokens()) == pairs
    assert h.result() is res


def test_streaming_interleaves_two_requests():
    cfg = _cfg()
    params = _params(cfg)
    rng = np.random.default_rng(3)
    p1 = rng.integers(0, cfg.vocab_size, (8,), dtype=np.int32)
    p2 = rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32)
    eng = _engine(cfg, params, prefix=False)
    h1 = eng.submit(p1, max_new_tokens=5)
    h2 = eng.submit(p2, max_new_tokens=5)
    out = eng.run()              # run() is sugar over the same stream
    t1 = list(h1.tokens())
    t2 = list(h2.tokens())
    np.testing.assert_array_equal([t for t, _ in t1],
                                  out["results"][int(h1)].tokens)
    np.testing.assert_array_equal([t for t, _ in t2],
                                  out["results"][int(h2)].tokens)
    assert h1.done() and h2.done()
