"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import kv_reuse, routing
from repro.kernels import ref
from repro.quant import dequantize, quantize_rtn

SET = dict(max_examples=25, deadline=None)


@given(T=st.integers(1, 300), keep=st.floats(0.05, 1.0))
@settings(**SET)
def test_capacity_invariants(T, keep):
    c = routing.capacity(T, keep)
    assert 1 <= c <= T
    assert c >= min(T, int(np.ceil(T * keep)))   # never truncates below target


@given(st.data())
@settings(**SET)
def test_select_topc_contains_topk(data):
    T = data.draw(st.integers(4, 64))
    C = data.draw(st.integers(1, T))
    score = np.asarray(data.draw(st.lists(
        st.floats(-10, 10, allow_nan=False), min_size=T, max_size=T)),
        np.float32)
    idx = np.asarray(routing.select_topc(jnp.asarray(score[None]), C)[0])
    assert np.all(np.diff(idx) > 0)              # strictly ascending
    assert len(set(idx.tolist())) == C           # distinct positions
    # tie-robust top-C: every selected score ≥ the C-th largest score
    thr = np.sort(score)[::-1][C - 1]
    assert np.all(score[idx] >= thr)


@given(st.data())
@settings(**SET)
def test_scatter_gather_identity(data):
    B = data.draw(st.integers(1, 3))
    T = data.draw(st.integers(2, 32))
    C = data.draw(st.integers(1, T))
    rng = np.random.default_rng(data.draw(st.integers(0, 1000)))
    x = rng.standard_normal((B, T, 4)).astype(np.float32)
    idx = np.stack([np.sort(rng.choice(T, C, replace=False))
                    for _ in range(B)])
    g = routing.gather_tokens(jnp.asarray(x), jnp.asarray(idx))
    s = routing.scatter_tokens(g, jnp.asarray(idx), T)
    # scatter(gather(x)) == x on selected rows, 0 elsewhere
    mask = np.zeros((B, T, 1), np.float32)
    for b in range(B):
        mask[b, idx[b]] = 1.0
    np.testing.assert_allclose(np.asarray(s), x * mask, rtol=1e-6)


@given(st.data())
@settings(**SET)
def test_kv_view_idempotent_when_nothing_executes(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 1000)))
    B, T, H, D = 1, data.draw(st.integers(1, 16)), 2, 4
    base = rng.standard_normal((B, T, H, D)).astype(np.float32)
    new = rng.standard_normal((B, T, H, D)).astype(np.float32)
    view = (jnp.asarray(base), jnp.asarray(base))
    out = kv_reuse.merge_view(view, jnp.asarray(new), jnp.asarray(new),
                              jnp.zeros((B, T)))
    np.testing.assert_array_equal(np.asarray(out[0]), base)
    out2 = kv_reuse.merge_view(view, jnp.asarray(new), jnp.asarray(new),
                               jnp.ones((B, T)))
    np.testing.assert_array_equal(np.asarray(out2[0]), new)


@given(st.data())
@settings(**SET)
def test_int4_rtn_error_bound_property(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 10_000)))
    K = data.draw(st.sampled_from([64, 128, 256]))
    N = data.draw(st.integers(1, 16))
    G = data.draw(st.sampled_from([32, 64, K]))
    amp = data.draw(st.floats(1e-4, 10.0))
    w = (rng.standard_normal((K, N)) * amp).astype(np.float32)
    codes, scale = quantize_rtn(jnp.asarray(w), G, pow2_scales=True)
    wd = np.asarray(dequantize(codes, scale))
    s_full = np.repeat(np.asarray(scale), G, axis=0)
    assert np.all(np.abs(w - wd) <= s_full / 2 * (1 + 1e-5) + 1e-9)


@given(st.data())
@settings(max_examples=10, deadline=None)
def test_attention_kv_permutation_invariance(data):
    """Paper §4.4.4: attention output is invariant to KV order when
    positions travel with the entries (sum-based reduction)."""
    rng = np.random.default_rng(data.draw(st.integers(0, 1000)))
    Tk = data.draw(st.integers(2, 24))
    q = rng.standard_normal((1, 1, 2, 8)).astype(np.float32)
    k = rng.standard_normal((1, Tk, 2, 8)).astype(np.float32)
    v = rng.standard_normal((1, Tk, 2, 8)).astype(np.float32)
    from repro.models.attention import chunked_attention
    qpos = jnp.full((1, 1), Tk)                  # attend to everything
    perm = rng.permutation(Tk)
    out1 = chunked_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                             q_positions=qpos, causal=True, chunk=Tk,
                             kv_positions=jnp.arange(Tk))
    out2 = chunked_attention(jnp.asarray(q), jnp.asarray(k[:, perm]),
                             jnp.asarray(v[:, perm]),
                             q_positions=qpos, causal=True, chunk=Tk,
                             kv_positions=jnp.asarray(perm))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Engine round-trip: speculative decoding == plain decoding on the same
# KV path, whatever the shapes (docs/speculative.md identity claim).
# Shapes are drawn from small fixed pools so jit compiles are reused
# across examples; plain-engine references are memoized per shape.
# ---------------------------------------------------------------------------

from repro.configs import get_config                      # noqa: E402
from repro.core.routing import neutral_router_bias        # noqa: E402
from repro.models import model as M                       # noqa: E402
from repro.serve.engine import ContinuousBatchingEngine   # noqa: E402
from repro.serve.faults import Fault                      # noqa: E402

KEY = jax.random.PRNGKey(0)
_ENGINE_CACHE = {}


def _smoke():
    if "cfg" not in _ENGINE_CACHE:
        cfg = get_config("llama2-7b").smoke()
        _ENGINE_CACHE["cfg"] = cfg
        _ENGINE_CACHE["params"] = neutral_router_bias(
            M.init_params(KEY, cfg))
    return _ENGINE_CACHE["cfg"], _ENGINE_CACHE["params"]


def _engine_tokens(kv_mode, spec_k, lens, max_new, faults=()):
    cfg, params = _smoke()
    eng = ContinuousBatchingEngine(cfg, params, max_slots=3, max_len=48,
                                   kv_mode=kv_mode, spec_k=spec_k,
                                   faults=list(faults))
    rng = np.random.default_rng(0)
    uids = [eng.submit(rng.integers(0, cfg.vocab_size, (l,),
                                    dtype=np.int32),
                       max_new_tokens=max_new) for l in lens]
    out = eng.run(KEY)
    return eng, out, [np.asarray(out["results"][u].tokens) for u in uids]


def _plain_tokens(kv_mode, lens, max_new):
    key = (kv_mode, lens, max_new)
    if key not in _ENGINE_CACHE:
        _ENGINE_CACHE[key] = _engine_tokens(kv_mode, 0, lens, max_new)[2]
    return _ENGINE_CACHE[key]


@given(kv_mode=st.sampled_from(["dense", "paged"]),
       spec_k=st.sampled_from([1, 2, 4, 8]),
       lens=st.sampled_from([(9, 14, 5), (6, 11, 8), (12, 4, 7)]),
       max_new=st.sampled_from([5, 9]))
@settings(max_examples=5, deadline=None)
def test_spec_engine_roundtrip_property(kv_mode, spec_k, lens, max_new):
    """Greedy speculative output is bit-identical to greedy plain output
    on the same KV path for any draft length and workload shape (the
    cross-path comparison is out of scope — dense and paged chains
    legitimately diverge in bf16)."""
    eng, out, toks = _engine_tokens(kv_mode, spec_k, lens, max_new)
    for got, want in zip(toks, _plain_tokens(kv_mode, lens, max_new)):
        np.testing.assert_array_equal(got, want)
    # unbiased draft at temperature 0: the draft pass IS the target pass
    assert out["stats"].spec_acceptance_rate == 1.0
    if kv_mode == "paged":
        assert eng.allocator.free_pages == eng.allocator.num_pages


@given(step=st.integers(0, 5))
@settings(max_examples=3, deadline=None)
def test_preemption_during_speculation_property(step):
    """An injected OOM (every free page hidden for one iteration) at ANY
    point of a paged speculative run: all requests still complete, the
    output stays bit-identical, and the page pool drains whole."""
    lens, max_new = (9, 14, 5, 11), 16
    eng, out, toks = _engine_tokens(
        "paged", 4, lens, max_new,
        faults=[Fault("oom", step=step, pages=0)])
    assert out["stats"].requests_completed == len(lens)
    for got, want in zip(toks, _plain_tokens("paged", lens, max_new)):
        np.testing.assert_array_equal(got, want)
    assert eng.allocator.free_pages == eng.allocator.num_pages
