"""INT4 RTN quantization (paper §5.1 / §4.2): error bounds, pow2 scales,
param-tree transformation, end-to-end quantized model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.quant import dequantize, quantize_params, quantize_rtn

KEY = jax.random.PRNGKey(0)


def test_rtn_roundtrip_error_bound():
    w = jax.random.normal(KEY, (256, 64)) * 0.05
    codes, scale = quantize_rtn(w, 128, pow2_scales=False)
    wd = dequantize(codes, scale)
    # symmetric RTN: |err| <= scale/2 per element
    G = 128
    s_full = np.repeat(np.asarray(scale), G, axis=0)
    assert np.all(np.abs(np.asarray(w) - np.asarray(wd)) <= s_full / 2 + 1e-7)


def test_pow2_scales_are_pow2():
    w = jax.random.normal(KEY, (256, 32))
    _, scale = quantize_rtn(w, 64, pow2_scales=True)
    lg = np.log2(np.asarray(scale))
    np.testing.assert_allclose(lg, np.round(lg), atol=1e-6)


def test_codes_in_int4_range():
    w = jax.random.normal(KEY, (128, 16)) * 3.0
    codes, _ = quantize_rtn(w, 128)
    assert codes.dtype == jnp.int8
    assert int(codes.min()) >= -8 and int(codes.max()) <= 7


def test_quantize_params_structure():
    cfg = get_config("llama2-7b").smoke()
    params = M.init_params(KEY, cfg)
    qp = quantize_params(params, group_size=128, min_size=1 << 12)
    leaves = jax.tree_util.tree_leaves_with_path(qp)
    names = {jax.tree_util.keystr(p) for p, _ in leaves}
    assert any("w_int" in n for n in names)
    assert any("scale" in n for n in names)
    # routers stay unquantized (tiny)
    assert any("router" in n and n.endswith("['w']") for n in names)


def test_quantized_model_close_to_dense():
    cfg = get_config("llama2-7b").smoke()
    params = M.init_params(KEY, cfg)
    qp = quantize_params(params, group_size=64, min_size=1 << 12)
    toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    lg_d, _, _ = M.prefill(params, {"tokens": toks}, cfg)
    lg_q, _, _ = M.prefill(qp, {"tokens": toks}, cfg)
    d = np.asarray(lg_d, np.float32)
    q = np.asarray(lg_q, np.float32)
    # int4 weights perturb logits but preserve the distribution's shape
    corr = np.corrcoef(d.ravel(), q.ravel())[0, 1]
    assert corr > 0.9
