"""INT4 RTN quantization (paper §5.1 / §4.2): error bounds, pow2 scales,
param-tree transformation, end-to-end quantized model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.quant import dequantize, quantize_params, quantize_rtn

KEY = jax.random.PRNGKey(0)


def test_rtn_roundtrip_error_bound():
    w = jax.random.normal(KEY, (256, 64)) * 0.05
    codes, scale = quantize_rtn(w, 128, pow2_scales=False)
    wd = dequantize(codes, scale)
    # symmetric RTN: |err| <= scale/2 per element
    G = 128
    s_full = np.repeat(np.asarray(scale), G, axis=0)
    assert np.all(np.abs(np.asarray(w) - np.asarray(wd)) <= s_full / 2 + 1e-7)


def test_pow2_scales_are_pow2():
    w = jax.random.normal(KEY, (256, 32))
    _, scale = quantize_rtn(w, 64, pow2_scales=True)
    lg = np.log2(np.asarray(scale))
    np.testing.assert_allclose(lg, np.round(lg), atol=1e-6)


def test_codes_in_int4_range():
    w = jax.random.normal(KEY, (128, 16)) * 3.0
    codes, _ = quantize_rtn(w, 128)
    assert codes.dtype == jnp.int8
    assert int(codes.min()) >= -8 and int(codes.max()) <= 7


def test_quantize_rtn_non_divisible_group_pads():
    """K not a multiple of the group size: the final group is zero-padded
    (masked amax) instead of silently skipping the weight."""
    K, N, G = 200, 16, 128
    w = jax.random.normal(KEY, (K, N)) * 0.05
    codes, scale = quantize_rtn(w, G, pow2_scales=True)
    assert codes.shape == (256, N) and scale.shape == (2, N)
    # padding rows are zero codes: they add nothing to any accumulation
    assert int(jnp.abs(codes[K:]).max()) == 0
    # real rows round-trip within the RTN bound
    wd = dequantize(codes, scale, k=K)
    s_full = np.repeat(np.asarray(scale), G, axis=0)[:K]
    assert np.all(np.abs(np.asarray(w) - np.asarray(wd))
                  <= s_full / 2 * (1 + 1e-5) + 1e-7)
    # and the padded-group amax is the masked amax of the real rows only
    amax_real = np.abs(np.asarray(w[G:], np.float32)).max(axis=0)
    assert np.all(np.asarray(scale[1]) >= amax_real / 7 - 1e-9)


def test_quantize_params_non_divisible_d_ff():
    """A config whose d_ff is not a group multiple must still quantize its
    down-projection (input dim d_ff) — previously silently skipped —
    and the quantized model must run on both dispatch paths."""
    cfg = dataclasses.replace(get_config("llama2-7b").smoke(), d_ff=200)
    params = M.init_params(KEY, cfg)
    # min_size 4k: catches the [200, 128] down-projection but leaves the
    # (tiny) routers dense
    qp = quantize_params(params, group_size=128, min_size=1 << 12)
    down = qp["stack"]["stage0"]["pos0"]["ffn"]["inner"]["down"]
    assert "w_int" in down, "non-divisible d_ff weight was skipped"
    assert down["w_int"].shape[0] == 256          # padded to 2 groups
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    lg_j, _, _ = M.prefill(qp, {"tokens": toks}, cfg)
    lg_k, _, _ = M.prefill(qp, {"tokens": toks},
                           dataclasses.replace(cfg, use_kernels=True))
    d = np.asarray(lg_j, np.float32)
    k = np.asarray(lg_k, np.float32)
    assert np.linalg.norm(k - d) / np.linalg.norm(d) < 0.1


def test_quantize_params_structure():
    cfg = get_config("llama2-7b").smoke()
    params = M.init_params(KEY, cfg)
    qp = quantize_params(params, group_size=128, min_size=1 << 12)
    leaves = jax.tree_util.tree_leaves_with_path(qp)
    names = {jax.tree_util.keystr(p) for p, _ in leaves}
    assert any("w_int" in n for n in names)
    assert any("scale" in n for n in names)
    # routers stay unquantized (tiny)
    assert any("router" in n and n.endswith("['w']") for n in names)


def test_quantized_model_close_to_dense():
    cfg = get_config("llama2-7b").smoke()
    params = M.init_params(KEY, cfg)
    qp = quantize_params(params, group_size=64, min_size=1 << 12)
    toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    lg_d, _, _ = M.prefill(params, {"tokens": toks}, cfg)
    lg_q, _, _ = M.prefill(qp, {"tokens": toks}, cfg)
    d = np.asarray(lg_d, np.float32)
    q = np.asarray(lg_q, np.float32)
    # int4 weights perturb logits but preserve the distribution's shape
    corr = np.corrcoef(d.ravel(), q.ravel())[0, 1]
    assert corr > 0.9
