"""Unit tests for SkipGPT routing (core/routing.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import routing


@pytest.fixture
def cfg():
    return get_config("qwen3-8b").smoke()


def test_router_logits_shape(cfg):
    p = routing.router_init(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((2, 5, cfg.d_model), jnp.bfloat16)
    lg = routing.router_logits(p, x)
    assert lg.shape == (2, 5, 2) and lg.dtype == jnp.float32


def test_gate_deterministic_inference(cfg):
    logits = jnp.array([[[0.0, 1.0], [1.0, 0.0], [0.3, 0.3]]])
    gate, p_keep = routing.gate_from_logits(logits, None, cfg, train=False)
    np.testing.assert_array_equal(np.asarray(gate), [[1.0, 0.0, 0.0]])
    assert float(p_keep[0, 0]) > 0.5


def test_gate_straight_through_gradient(cfg):
    """The ST estimator must pass gradients to the router weights."""
    p = routing.router_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))

    def loss(p):
        lg = routing.router_logits(p, x)
        gate, _ = routing.gate_from_logits(lg, jax.random.PRNGKey(2), cfg,
                                           train=True)
        return (gate * 2.0).sum()

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["w"]).sum()) > 0.0


def test_gate_is_binary_in_train(cfg):
    p = routing.router_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    lg = routing.router_logits(p, x)
    gate, _ = routing.gate_from_logits(lg, jax.random.PRNGKey(3), cfg, True)
    vals = np.unique(np.asarray(gate))
    assert set(vals).issubset({0.0, 1.0})


def test_capacity_bounds():
    assert routing.capacity(100, 0.75) == 80      # rounded up to 8
    assert routing.capacity(100, 1.0) == 100
    assert routing.capacity(4, 0.25) == 4         # min(T, multiple)
    assert routing.capacity(1024, 0.75) == 768


def test_select_topc_sorted_and_top():
    score = jnp.array([[0.1, 0.9, 0.5, 0.8, 0.2]])
    idx = routing.select_topc(score, 3)
    np.testing.assert_array_equal(np.asarray(idx[0]), [1, 2, 3])
    assert np.all(np.diff(np.asarray(idx[0])) > 0)


def test_gather_scatter_roundtrip():
    x = jnp.arange(2 * 6 * 3, dtype=jnp.float32).reshape(2, 6, 3)
    idx = jnp.array([[0, 2, 5], [1, 3, 4]])
    g = routing.gather_tokens(x, idx)
    assert g.shape == (2, 3, 3)
    s = routing.scatter_tokens(g, idx, 6)
    # selected rows recovered, others zero
    np.testing.assert_allclose(np.asarray(s[0, 2]), np.asarray(x[0, 2]))
    np.testing.assert_allclose(np.asarray(s[1, 0]), 0.0)


def test_router_stats_targets_keep_prob(cfg):
    p_keep = jnp.full((4, 8), cfg.skip.keep_prob)
    stats = routing.router_stats(p_keep, jnp.ones((4, 8)), cfg)
    assert float(stats["router_loss"]) < 1e-9
