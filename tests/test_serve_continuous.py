"""Continuous-batching serving core: ragged (per-sequence position) decode,
slot-pool admission/eviction/reuse, bucketed prefill exactness, and
token-identity of the continuous engine vs. running each request alone."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import (ContinuousBatchingEngine, ServeEngine,
                                init_pool, pool_insert)
from repro.serve.scheduler import Request, Scheduler, can_bucket

KEY = jax.random.PRNGKey(0)


def _cfg(name="llama2-7b", **over):
    cfg = get_config(name).smoke()
    return dataclasses.replace(cfg, **over) if over else cfg


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (l,), dtype=np.int32)
            for l in lens]


# ---------------------------------------------------------------------------
# Ragged decode (model level): a batch at different positions must match
# each sequence decoded alone.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["bthd", "bhtd"])
def test_ragged_decode_matches_sequential(layout):
    cfg = _cfg(kv_cache_layout=layout)
    params = M.init_params(KEY, cfg)
    max_len = 32
    p1, p2 = _prompts(cfg, [10, 6])

    lg1, c1, _ = M.prefill(params, {"tokens": jnp.asarray(p1[None])}, cfg,
                           pad_to=max_len)
    lg2, c2, _ = M.prefill(params, {"tokens": jnp.asarray(p2[None])}, cfg,
                           pad_to=max_len)
    pool = init_pool(cfg, 2, max_len)
    pool = pool_insert(pool, c1, 0, cfg)
    pool = pool_insert(pool, c2, 1, cfg)
    # bhtd reference caches need the pool path too (prefill collects bthd)
    ref1 = pool_insert(init_pool(cfg, 1, max_len), c1, 0, cfg)
    ref2 = pool_insert(init_pool(cfg, 1, max_len), c2, 0, cfg)

    t = np.array([10, 6], np.int32)
    tok = np.array([int(jnp.argmax(lg1[0])), int(jnp.argmax(lg2[0]))],
                   np.int32)
    for _ in range(4):
        lg_pool, pool, _ = M.decode_step(
            params, pool, {"tokens": jnp.asarray(tok[:, None])},
            jnp.asarray(t), cfg)
        lr1, ref1, _ = M.decode_step(
            params, ref1, {"tokens": jnp.asarray(tok[0:1, None])},
            jnp.asarray(t[0:1]), cfg)
        lr2, ref2, _ = M.decode_step(
            params, ref2, {"tokens": jnp.asarray(tok[1:2, None])},
            jnp.asarray(t[1:2]), cfg)
        ref = jnp.concatenate([lr1, lr2], axis=0)
        np.testing.assert_allclose(np.asarray(lg_pool, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-2, atol=2e-2)
        np.testing.assert_array_equal(np.asarray(jnp.argmax(lg_pool, -1)),
                                      np.asarray(jnp.argmax(ref, -1)))
        tok = np.asarray(jnp.argmax(lg_pool, -1), np.int32)
        t = t + 1


def test_scalar_t_still_broadcasts():
    """Lock-step callers pass a scalar position; it must keep working."""
    cfg = _cfg()
    params = M.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    _, cache, _ = M.prefill(params, {"tokens": toks[:, :-1]}, cfg, pad_to=12)
    lg_s, _, _ = M.decode_step(params, cache, {"tokens": toks[:, -1:]},
                               jnp.int32(11), cfg)
    _, cache2, _ = M.prefill(params, {"tokens": toks[:, :-1]}, cfg, pad_to=12)
    lg_v, _, _ = M.decode_step(params, cache2, {"tokens": toks[:, -1:]},
                               jnp.full((2,), 11, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(lg_s, np.float32),
                               np.asarray(lg_v, np.float32))


# ---------------------------------------------------------------------------
# Scheduler: slot admission / eviction / reuse round-trip
# ---------------------------------------------------------------------------

def test_slot_admission_eviction_reuse():
    sched = Scheduler(max_slots=2, max_len=64)
    for uid in range(5):
        sched.submit(Request(uid=uid, tokens=np.zeros(8, np.int32),
                             max_new_tokens=4))
    first = sched.admit()
    assert [r.uid for _, r in first] == [0, 1]
    assert sched.free_slots == 0
    assert sched.admit() == []                   # pool exhausted
    slot0 = first[0][0]
    # evict one -> its slot is reused by the next FIFO request
    from repro.serve.scheduler import ActiveRequest
    for slot, req in first:
        sched.activate(ActiveRequest(req=req, slot=slot, pos=8))
    sched.release(slot0)
    assert sched.free_slots == 1
    nxt = sched.admit()
    assert [(s, r.uid) for s, r in nxt] == [(slot0, 2)]
    # round-trip: release everything, all slots free again
    sched.activate(ActiveRequest(req=nxt[0][1], slot=slot0, pos=8))
    for slot in list(sched.active):
        sched.release(slot)
    assert sched.free_slots == 2 and not sched.active
    assert [r.uid for r in sched.queue] == [3, 4]


def test_scheduler_rejects_oversized_prompt():
    sched = Scheduler(max_slots=1, max_len=16)
    with pytest.raises(ValueError):
        sched.submit(Request(uid=0, tokens=np.zeros(16, np.int32),
                             max_new_tokens=1))


# ---------------------------------------------------------------------------
# Bucketed prefill: padded prompt + last_index must be logit-identical
# ---------------------------------------------------------------------------

def test_bucketed_prefill_matches_exact():
    cfg = _cfg()
    assert can_bucket(cfg)
    params = M.init_params(KEY, cfg)
    (p,) = _prompts(cfg, [13])
    lg_exact, _, _ = M.prefill(params, {"tokens": jnp.asarray(p[None])}, cfg)
    padded = np.pad(p, (0, 3))                   # bucket 16
    lg_buck, _, _ = M.prefill(params, {"tokens": jnp.asarray(padded[None])},
                              cfg, last_index=jnp.asarray([12], jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_buck, np.float32),
                               np.asarray(lg_exact, np.float32),
                               rtol=2e-2, atol=2e-2)
    assert int(jnp.argmax(lg_buck[0])) == int(jnp.argmax(lg_exact[0]))


def test_engine_rejects_explicit_buckets_on_unbucketable_cfg():
    """Padding corrupts ring/SSM state — explicit buckets must not bypass
    the can_bucket() exactness guard."""
    cfg = get_config("gemma3-12b").smoke()
    params = M.init_params(KEY, cfg)
    with pytest.raises(ValueError, match="exact-length prefill"):
        ContinuousBatchingEngine(cfg, params, max_slots=1, max_len=32,
                                 prefill_buckets=(16, 32))


def test_can_bucket_gating():
    assert can_bucket(_cfg())                    # all-global, masked mode
    assert not can_bucket(get_config("gemma3-12b").smoke())   # local ring
    assert not can_bucket(get_config("jamba-v0.1-52b").smoke())  # ssm
    g = _cfg()
    g = dataclasses.replace(g, skip=dataclasses.replace(g.skip,
                                                        mode="gather"))
    assert not can_bucket(g)                     # capacity depends on T


# ---------------------------------------------------------------------------
# Engine: mixed-length workload is token-identical to per-request runs
# ---------------------------------------------------------------------------

def _check_engine_token_identity(cfg, lens, max_new, max_slots, max_len):
    params = M.init_params(KEY, cfg)
    prompts = _prompts(cfg, lens)
    eng = ContinuousBatchingEngine(cfg, params, max_slots=max_slots,
                                   max_len=max_len)
    uids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    out = eng.run()
    assert out["stats"].requests_completed == len(prompts)
    ref_eng = ServeEngine(cfg, params, max_len=max_len)
    for uid, p in zip(uids, prompts):
        ref = ref_eng.generate(p[None, :], max_new)["tokens"][0]
        np.testing.assert_array_equal(out["results"][uid].tokens, ref)
        r = out["results"][uid]
        assert r.prompt_len == len(p)
        assert r.ttft_s >= 0.0 and r.decode_s >= 0.0
    return out


def test_engine_token_identity_mixed_lengths():
    out = _check_engine_token_identity(_cfg(), lens=[9, 16, 5, 21],
                                       max_new=5, max_slots=2, max_len=48)
    # 4 requests through 2 slots: admission must have recycled slots
    assert out["stats"].decode_tokens == 4 * 5


def test_engine_token_identity_local_ring():
    """Sliding-window (ring cache) arch decodes ragged correctly; prompts
    straddle the window size (16) so both ring regimes are hit."""
    cfg = get_config("gemma3-12b").smoke()
    _check_engine_token_identity(cfg, lens=[12, 20], max_new=4,
                                 max_slots=2, max_len=40)


def test_engine_token_identity_bhtd_layout():
    """Head-major pool layout: insert-time transpose + per-row writes.
    Reference tokens come from the default-layout engine (same math)."""
    cfg_b = _cfg(kv_cache_layout="bhtd")
    params = M.init_params(KEY, cfg_b)
    prompts = _prompts(cfg_b, [7, 13])
    eng = ContinuousBatchingEngine(cfg_b, params, max_slots=2, max_len=32)
    uids = [eng.submit(p, max_new_tokens=4) for p in prompts]
    out = eng.run()
    ref_eng = ContinuousBatchingEngine(_cfg(), params, max_slots=2,
                                       max_len=32)
    ruids = [ref_eng.submit(p, max_new_tokens=4) for p in prompts]
    ref = ref_eng.run()
    for u, ru in zip(uids, ruids):
        np.testing.assert_array_equal(out["results"][u].tokens,
                                      ref["results"][ru].tokens)


def test_engine_stop_token_evicts_early():
    cfg = _cfg()
    params = M.init_params(KEY, cfg)
    (p,) = _prompts(cfg, [8])
    # discover the greedy continuation, then stop on its second token
    eng = ContinuousBatchingEngine(cfg, params, max_slots=1, max_len=32)
    uid = eng.submit(p, max_new_tokens=6)
    free_run = eng.run()["results"][uid].tokens
    stop = int(free_run[1])
    eng2 = ContinuousBatchingEngine(cfg, params, max_slots=1, max_len=32)
    uid2 = eng2.submit(p, max_new_tokens=6, stop_token=stop)
    res = eng2.run()["results"][uid2]
    assert res.finish_reason == "stop"
    assert res.tokens.shape[0] == 2 and int(res.tokens[-1]) == stop


def test_engine_measured_kv_saving_with_skipping_router():
    """With the keep-warm-start bias removed the router actually skips, and
    the engine's kv_saved_fraction — measured from logged gates — lands in
    the paper's regime, per request and in aggregate."""
    from repro.core.routing import neutral_router_bias

    cfg = _cfg()
    params = neutral_router_bias(M.init_params(KEY, cfg))
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_len=48)
    for p in _prompts(cfg, [10, 14, 6]):
        eng.submit(p, max_new_tokens=6)
    out = eng.run()
    s = out["stats"]
    assert 0.0 < s.kv_saved_fraction < 0.5
    for r in out["results"].values():
        assert r.kv_dense > 0
        assert 0.0 <= r.kv_saved_fraction <= 0.5
