"""Tensor-parallel sharded serving: the continuous-batching engine under an
active serve-mode ``ShardingPolicy``.

Runs IN-PROCESS against however many devices this process sees — the
multi-device CI job provides 8 simulated host devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and sets
``REQUIRE_MULTIDEVICE=1`` so these tests FAIL (not skip) if the topology
is missing; on a plain 1-device host (tier-1) they skip.

The acceptance bar is *token identity*: the sharded engine must emit
bit-identical token ids to the unsharded engine for dense and paged KV
modes, with chunked prefill and under forced preemption — sharding is a
pure layout change; the scheduler, allocator and history indirection stay
host-side and replicated.
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.routing import neutral_router_bias
from repro.distributed.compat import make_mesh
from repro.models import model as M
from repro.serve.engine import ContinuousBatchingEngine

KEY = jax.random.PRNGKey(0)
REQUIRED = 8


def _need_devices(n: int = REQUIRED) -> None:
    have = jax.device_count()
    if have >= n:
        return
    if os.environ.get("REQUIRE_MULTIDEVICE"):
        pytest.fail(
            f"REQUIRE_MULTIDEVICE is set but only {have} device(s) are "
            f"visible — the CI job must export XLA_FLAGS="
            f"--xla_force_host_platform_device_count={REQUIRED}")
    pytest.skip(f"needs {n} devices (got {have}); set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={REQUIRED}")


def _cfg(**over):
    # 8 query/KV heads so the head axis splits cleanly over model=8
    cfg = dataclasses.replace(get_config("llama2-7b").smoke(),
                              num_heads=8, num_kv_heads=8, head_dim=16)
    return dataclasses.replace(cfg, **over) if over else cfg


def _params(cfg):
    return neutral_router_bias(M.init_params(KEY, cfg))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (l,), dtype=np.int32)
            for l in lens]


def _axes(spec):
    """Flatten a PartitionSpec into the mesh axis names it uses."""
    out = []
    for ax in spec:
        if ax is None:
            continue
        out.extend(ax if isinstance(ax, tuple) else (ax,))
    return out


def _run_pair(cfg, params, prompts, mesh, max_new=10, **kw):
    """Run the same workload unsharded and sharded; return both outputs."""
    outs = []
    for m in (None, mesh):
        eng = ContinuousBatchingEngine(cfg, params, mesh=m, **kw)
        for p in prompts:
            eng.submit(p, max_new_tokens=max_new)
        outs.append((eng, eng.run()))
    return outs


def _assert_identical(base, shard):
    _, ob = base
    _, os_ = shard
    assert set(ob["results"]) == set(os_["results"])
    for uid in ob["results"]:
        b, s = ob["results"][uid], os_["results"][uid]
        np.testing.assert_array_equal(b.tokens, s.tokens)
        assert b.finish_reason == s.finish_reason
        assert (b.kv_stored, b.kv_dense) == (s.kv_stored, s.kv_dense)
    sb, ss = ob["stats"], os_["stats"]
    assert sb.decode_tokens == ss.decode_tokens
    assert sb.prefill_tokens == ss.prefill_tokens
    assert sb.requests_completed == ss.requests_completed
    assert sb.preemptions == ss.preemptions


@pytest.mark.slow
def test_dense_sharded_identity_tp8():
    _need_devices()
    cfg = _cfg()
    params = _params(cfg)
    mesh = make_mesh((1, 8), ("data", "model"))
    base, shard = _run_pair(cfg, params,
                            _prompts(cfg, [7, 19, 12, 30, 5, 23]),
                            mesh, max_slots=3, max_len=48)
    _assert_identical(base, shard)


@pytest.mark.slow
def test_dense_sharded_pool_rows_are_head_sharded():
    """The slot pool's KV rows live 1/TP-per-device: each addressable shard
    holds Hkv/TP heads, so per-chip KV HBM drops ~1/TP."""
    _need_devices()
    cfg = _cfg()
    params = _params(cfg)
    mesh = make_mesh((1, 8), ("data", "model"))
    eng = ContinuousBatchingEngine(cfg, params, max_slots=3, max_len=48,
                                   mesh=mesh)
    specs = jax.tree_util.tree_leaves(eng._pool_sh)
    assert specs, "no pool shardings built"
    k_sh = eng._pool_sh["stage0"]["pos0"]["k"]
    assert "model" in _axes(k_sh.spec)
    # materialize the pool exactly as run() does and check shard shapes
    from repro.serve.engine import init_pool
    pool = jax.device_put(init_pool(cfg, 3, 48), eng._pool_sh)
    leaf = pool["stage0"]["pos0"]["k"]          # [slots, T, Hkv, dh]
    shard = leaf.addressable_shards[0].data
    assert shard.shape[-2] == cfg.num_kv_heads // 8
    assert shard.size == leaf.size // 8


@pytest.mark.slow
def test_dense_sharded_identity_bhtd_data_axis():
    """Head-major pool layout on a (data=4, model=2) mesh: batch over the
    data axis, heads over model — the full production-mesh shape."""
    _need_devices()
    cfg = _cfg(kv_cache_layout="bhtd")
    params = _params(cfg)
    mesh = make_mesh((4, 2), ("data", "model"))
    base, shard = _run_pair(cfg, params, _prompts(cfg, [9, 17, 26, 6]),
                            mesh, max_slots=4, max_len=40)
    _assert_identical(base, shard)


@pytest.mark.slow
def test_chunked_prefill_sharded_identity():
    _need_devices()
    cfg = _cfg()
    params = _params(cfg)
    mesh = make_mesh((1, 8), ("data", "model"))
    base, shard = _run_pair(cfg, params, _prompts(cfg, [21, 9, 14, 6]),
                            mesh, max_slots=2, max_len=40, prefill_chunk=8)
    _assert_identical(base, shard)
    assert shard[1]["stats"].prefill_chunks > len(
        shard[1]["results"])               # chunking actually engaged


@pytest.mark.slow
def test_paged_sharded_identity():
    _need_devices()
    cfg = _cfg()
    params = _params(cfg)
    mesh = make_mesh((1, 8), ("data", "model"))
    base, shard = _run_pair(cfg, params, _prompts(cfg, [9, 21, 14, 6],
                                                  seed=1),
                            mesh, max_slots=2, max_len=40,
                            kv_mode="paged", page_size=8)
    _assert_identical(base, shard)
    eng, out = shard
    # page pools are head-sharded; entry metadata replicated
    assert "model" in _axes(eng._store_sh["k_pages"].spec)
    assert not _axes(eng._store_sh["pos_pages"].spec)
    assert out["stats"].kv_entries_saved_fraction == \
        base[1]["stats"].kv_entries_saved_fraction


@pytest.mark.slow
def test_paged_sharded_identity_under_forced_preemption():
    """A page pool too small for both residents forces mid-decode
    preemption; the sharded engine preempts at the same step and re-decodes
    to identical tokens (the allocator is host-side and never sharded)."""
    _need_devices()
    cfg = _cfg()
    params = _params(cfg)
    mesh = make_mesh((1, 8), ("data", "model"))
    base, shard = _run_pair(cfg, params, _prompts(cfg, [8, 8], seed=1),
                            mesh, max_new=16, max_slots=2, max_len=48,
                            kv_mode="paged", page_size=8, num_pages=6)
    _assert_identical(base, shard)
    assert shard[1]["stats"].preemptions >= 1


@pytest.mark.slow
def test_paged_chunked_sharded_identity():
    _need_devices()
    cfg = _cfg()
    params = _params(cfg)
    mesh = make_mesh((1, 8), ("data", "model"))
    base, shard = _run_pair(cfg, params, _prompts(cfg, [21, 9, 14, 6],
                                                  seed=1),
                            mesh, max_slots=2, max_len=40,
                            kv_mode="paged", page_size=8, prefill_chunk=8)
    _assert_identical(base, shard)


@pytest.mark.slow
@pytest.mark.parametrize("kv_mode", ["dense", "paged"])
def test_fused_decode_loop_sharded_identity(kv_mode):
    """The device-resident N-step epoch (``decode_steps > 1``) under TP8:
    the scan's carry (feed/t/active masks) stays replicated while cache
    and params ride their serve-mode shardings — tokens must match the
    *unsharded single-step* engine bit for bit, with one jitted dispatch
    per epoch on both sides of the mesh boundary."""
    _need_devices()
    cfg = _cfg()
    params = _params(cfg)
    mesh = make_mesh((1, 8), ("data", "model"))
    prompts = _prompts(cfg, [7, 19, 12, 30, 5])
    kw = dict(max_slots=3, max_len=48, kv_mode=kv_mode)
    base = ContinuousBatchingEngine(cfg, params, **kw)
    for p in prompts:
        base.submit(p, max_new_tokens=10)
    ob = base.run()
    shard = ContinuousBatchingEngine(cfg, params, mesh=mesh,
                                     decode_steps=8, **kw)
    for p in prompts:
        shard.submit(p, max_new_tokens=10)
    os_ = shard.run()
    for uid in ob["results"]:
        np.testing.assert_array_equal(ob["results"][uid].tokens,
                                      os_["results"][uid].tokens)
        assert ob["results"][uid].finish_reason == \
            os_["results"][uid].finish_reason
    assert os_["stats"].decode_dispatches < ob["stats"].decode_dispatches


@pytest.mark.slow
def test_sharded_rejects_bad_policy_mode():
    _need_devices(2)
    from repro.distributed.sharding import ShardingPolicy
    cfg = _cfg()
    params = _params(cfg)
    mesh = make_mesh((1, 2), ("data", "model"))
    pol = ShardingPolicy(mesh, cfg, mode="train")
    with pytest.raises(ValueError, match="serve-mode"):
        ContinuousBatchingEngine(cfg, params, mesh=mesh,
                                 sharding_policy=pol)
