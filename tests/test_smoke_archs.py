"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED config of the same family, runs one forward/train step on CPU with
finite outputs and correct shapes.  Full configs are exercised only by the
dry-run (ShapeDtypeStruct lowering, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B, T):
    if cfg.frontend == "token":
        b = {"tokens": jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)}
    else:
        b = {"embeds": jax.random.normal(KEY, (B, T, cfg.d_model),
                                         jnp.float32)}
    b["labels"] = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch).smoke()
    params = M.init_params(KEY, cfg)
    B, T = 2, 32
    batch = _batch(cfg, B, T)
    loss, metrics = jax.jit(lambda p, b, r: M.train_loss(p, b, r, cfg))(
        params, batch, jax.random.PRNGKey(1))
    assert np.isfinite(float(loss))
    assert 0.0 <= float(metrics["keep_frac"]) <= 1.0
    # gradient step produces finite updates
    grads = jax.grad(lambda p: M.train_loss(p, batch,
                                            jax.random.PRNGKey(1), cfg)[0])(
        params)
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_prefill_decode(arch):
    cfg = get_config(arch).smoke()
    params = M.init_params(KEY, cfg)
    B, T = 2, 16
    batch = _batch(cfg, B, T)
    batch.pop("labels")
    logits, cache, _ = M.prefill(params, batch, cfg, pad_to=T + 2)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    step_in = ({"tokens": jnp.argmax(logits, -1)[:, None]}
               if cfg.frontend == "token" else
               {"embeds": jax.random.normal(KEY, (B, 1, cfg.d_model),
                                            jnp.float32)})
    lg2, cache, _ = M.decode_step(params, cache, step_in, jnp.int32(T), cfg)
    assert lg2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg2, np.float32)).all()


def test_all_archs_registered():
    assert len(ASSIGNED_ARCHS) == 10
    for a in ASSIGNED_ARCHS:
        cfg = get_config(a)
        assert cfg.param_count() > 1e9          # full config is full-size
        assert cfg.num_layers % cfg.stage_len == 0
