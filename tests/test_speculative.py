"""Self-speculative decoding (docs/speculative.md): the correctness
battery the feature ships behind.

Three layers of proof, from the engine down:

1. **Differential identity** — greedy speculative decoding must be
   bit-identical to plain greedy decoding on the SAME engine path
   (spec-dense vs plain-dense, spec-paged vs plain-paged; cross-path
   comparisons are out of scope — dense and paged chains legitimately
   diverge in bf16).  Checked across draft lengths, the fused-epoch
   plain loop, kernel-backed matmuls, biased drafts, adversarially
   corrupted drafts, mid-window stop tokens, and preemption storms:
   the emitted chain is the verifier's greedy chain by construction
   (``greedy_verify``), so NO draft behaviour may change tokens.

2. **Rollback invariants** — the paged tentative-commit protocol must
   never leak or double-book pages.  Engine-level: a trim spy checks
   chain tightness and free-list conservation after every window, and
   KV accounting (entries appended / dense baseline) matches a
   never-speculated run exactly.  Allocator-level: the window protocol
   (ensure → append → trim → release) is fuzzed standalone, with a
   fixed-case mirror that runs even without Hypothesis.

3. **Distribution oracle** — the temperature>0 accept/resample helpers
   are pure numpy, so the speculative-sampling identity
   ``emitted_distribution(p_draft, p_target) == p_target`` is checked
   analytically (float64, no Monte Carlo), plus the per-window
   mechanics of ``speculative_accept_window``.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.routing import neutral_router_bias
from repro.kvcache.paged import PageAllocator
from repro.models import model as M
from repro.serve import sampling
from repro.serve.engine import ContinuousBatchingEngine
from repro.serve.faults import Fault
from repro.serve.scheduler import can_speculate

KEY = jax.random.PRNGKey(0)
LENS = (9, 14, 5, 11)
MAX_NEW = 10


def _cfg(name="llama2-7b", **over):
    cfg = get_config(name).smoke()
    return dataclasses.replace(cfg, **over) if over else cfg


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    # neutral bias => the router actually skips, so the paged reuse path
    # (gate-derived fresh_n) is exercised by every paged run below
    return cfg, neutral_router_bias(M.init_params(KEY, cfg))


def _run(cfg, params, *, kv_mode="dense", spec_k=0, lens=LENS,
         max_new=MAX_NEW, seed=0, override=None, stop_token=None, **kw):
    eng = ContinuousBatchingEngine(cfg, params, max_slots=3, max_len=48,
                                   kv_mode=kv_mode, spec_k=spec_k, **kw)
    if override is not None:
        eng.draft_override = override
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, (l,), dtype=np.int32)
               for l in lens]
    uids = [eng.submit(p, max_new_tokens=max_new, stop_token=stop_token)
            for p in prompts]
    out = eng.run(KEY)
    return eng, uids, out


def _toks(out, uids):
    return [np.asarray(out["results"][u].tokens) for u in uids]


def _assert_identical(out_a, uids_a, out_b, uids_b):
    for ta, tb in zip(_toks(out_a, uids_a), _toks(out_b, uids_b)):
        np.testing.assert_array_equal(ta, tb)


@pytest.fixture(scope="module")
def plain_dense(setup):
    cfg, params = setup
    _, uids, out = _run(cfg, params, kv_mode="dense")
    return uids, out


@pytest.fixture(scope="module")
def plain_paged(setup):
    cfg, params = setup
    _, uids, out = _run(cfg, params, kv_mode="paged")
    return uids, out


# ---------------------------------------------------------------------------
# 1. Differential identity: greedy spec == greedy plain, same path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_greedy_identity_dense(setup, plain_dense, k):
    cfg, params = setup
    uids_p, out_p = plain_dense
    eng, uids_s, out_s = _run(cfg, params, kv_mode="dense", spec_k=k)
    _assert_identical(out_p, uids_p, out_s, uids_s)
    st = out_s["stats"]
    assert st.spec_windows > 0
    assert st.spec_tokens_drafted > 0
    # unbiased draft at temperature 0: the draft pass IS the target pass
    assert st.spec_acceptance_rate == 1.0


@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_greedy_identity_paged(setup, plain_paged, k):
    cfg, params = setup
    uids_p, out_p = plain_paged
    eng, uids_s, out_s = _run(cfg, params, kv_mode="paged", spec_k=k)
    _assert_identical(out_p, uids_p, out_s, uids_s)
    assert out_s["stats"].spec_acceptance_rate == 1.0
    # tentative pages all returned: the pool is whole after the run
    assert eng.allocator.free_pages == eng.allocator.num_pages


def test_identity_vs_fused_epoch(setup):
    """The fused-epoch loop (decode_steps=4) and the speculative loop
    both claim bit-identity with plain single-step greedy — so they must
    match each other too, on both KV paths."""
    cfg, params = setup
    for kv_mode in ("dense", "paged"):
        _, uids_f, out_f = _run(cfg, params, kv_mode=kv_mode,
                                decode_steps=4)
        _, uids_s, out_s = _run(cfg, params, kv_mode=kv_mode, spec_k=4)
        _assert_identical(out_f, uids_f, out_s, uids_s)


def test_identity_with_kernels(setup):
    """Pallas-kernel matmuls claim decode identity with pure jnp — the
    speculative window must preserve it (tiny workload: interpret-mode
    kernels are slow)."""
    cfg, params = setup
    kcfg = _cfg(use_kernels=True)
    for kv_mode in ("dense", "paged"):
        _, uids_p, out_p = _run(kcfg, params, kv_mode=kv_mode,
                                lens=(6, 9), max_new=5)
        _, uids_s, out_s = _run(kcfg, params, kv_mode=kv_mode, spec_k=2,
                                lens=(6, 9), max_new=5)
        _assert_identical(out_p, uids_p, out_s, uids_s)


@pytest.mark.parametrize("kv_mode", ["dense", "paged"])
def test_all_reject_extreme(setup, plain_dense, plain_paged, kv_mode):
    """Adversarial draft: every proposal off-by-one from whatever the
    draft pass produced.  Acceptance collapses to 0 — every window emits
    exactly one (corrected) token — and the output must STILL be
    bit-identical plain greedy."""
    cfg, params = setup
    V = cfg.vocab_size
    eng, uids_s, out_s = _run(cfg, params, kv_mode=kv_mode, spec_k=4,
                              override=lambda uid, d: (d + 1) % V)
    uids_p, out_p = plain_dense if kv_mode == "dense" else plain_paged
    _assert_identical(out_p, uids_p, out_s, uids_s)
    st = out_s["stats"]
    assert st.spec_tokens_drafted > 0
    assert st.spec_tokens_accepted == 0
    assert st.spec_acceptance_rate == 0.0
    if kv_mode == "paged":
        # every rejected window rolled its tentative entries back, and
        # the rollback returned every page
        assert st.spec_entries_rolled_back > 0
        assert eng.allocator.free_pages == eng.allocator.num_pages


@pytest.mark.parametrize("kv_mode", ["dense", "paged"])
def test_random_corruption_identity(setup, plain_dense, plain_paged,
                                    kv_mode):
    """Randomly corrupted drafts => partial acceptance, arbitrary
    accept/reject boundaries inside windows — tokens still identical."""
    cfg, params = setup
    V = cfg.vocab_size
    rng = np.random.default_rng(7)

    def corrupt(uid, d):
        mask = rng.random(d.shape) < 0.5
        return np.where(mask, (d + rng.integers(1, V, d.shape)) % V,
                        d).astype(d.dtype)

    eng, uids_s, out_s = _run(cfg, params, kv_mode=kv_mode, spec_k=4,
                              override=corrupt)
    uids_p, out_p = plain_dense if kv_mode == "dense" else plain_paged
    _assert_identical(out_p, uids_p, out_s, uids_s)
    assert 0.0 <= out_s["stats"].spec_acceptance_rate <= 1.0
    if kv_mode == "paged":
        assert eng.allocator.free_pages == eng.allocator.num_pages


@pytest.mark.parametrize("kv_mode", ["dense", "paged"])
def test_biased_draft_identity(setup, plain_dense, plain_paged, kv_mode):
    """draft_keep < 1 biases the draft router toward skipping — the
    whole point of SELF-speculation.  Acceptance may drop; tokens may
    not."""
    cfg, params = setup
    eng, uids_s, out_s = _run(cfg, params, kv_mode=kv_mode, spec_k=4,
                              draft_keep=0.5)
    uids_p, out_p = plain_dense if kv_mode == "dense" else plain_paged
    _assert_identical(out_p, uids_p, out_s, uids_s)
    st = out_s["stats"]
    assert st.spec_tokens_drafted > 0
    assert 0.0 <= st.spec_acceptance_rate <= 1.0


@pytest.mark.parametrize("kv_mode", ["dense", "paged"])
def test_mid_window_stop_token(setup, kv_mode):
    """A stop token landing in the middle of an accepted window must
    truncate emission exactly where the plain engine would stop."""
    cfg, params = setup
    # discover a token the plain chain actually emits, then stop on it
    _, uids, out = _run(cfg, params, kv_mode=kv_mode, lens=(9,),
                        max_new=8)
    chain = _toks(out, uids)[0]
    stop = int(chain[2])
    _, uids_p, out_p = _run(cfg, params, kv_mode=kv_mode, lens=(9,),
                            max_new=8, stop_token=stop)
    _, uids_s, out_s = _run(cfg, params, kv_mode=kv_mode, spec_k=4,
                            lens=(9,), max_new=8, stop_token=stop)
    _assert_identical(out_p, uids_p, out_s, uids_s)
    rs = out_s["results"][uids_s[0]]
    rp = out_p["results"][uids_p[0]]
    assert rs.finish_reason == rp.finish_reason == "stop"
    assert int(_toks(out_s, uids_s)[0][-1]) == stop


def test_spec_sampled_run_completes(setup):
    """Temperature > 0: no bit-identity claim (that is what the
    distribution oracle below is for), but the stochastic accept path
    must run end to end on both KV paths and honor token budgets."""
    cfg, params = setup
    for kv_mode in ("dense", "paged"):
        eng, uids, out = _run(cfg, params, kv_mode=kv_mode, spec_k=4,
                              temperature=0.8, lens=(7, 10), max_new=6)
        for u in uids:
            assert out["results"][u].tokens.shape[0] == 6
        assert 0.0 <= out["stats"].spec_acceptance_rate <= 1.0
        # unbiased draft: identical distributions => accept ratio is 1
        assert out["stats"].spec_acceptance_rate == 1.0


def test_ctor_validation(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="spec_k"):
        ContinuousBatchingEngine(cfg, params, max_slots=2, max_len=32,
                                 spec_k=-1)
    with pytest.raises(ValueError, match="mutually exclusive"):
        ContinuousBatchingEngine(cfg, params, max_slots=2, max_len=32,
                                 spec_k=4, decode_steps=4)
    for bad_keep in (0.0, 1.5, -0.2):
        with pytest.raises(ValueError, match="draft_keep"):
            ContinuousBatchingEngine(cfg, params, max_slots=2, max_len=32,
                                     spec_k=2, draft_keep=bad_keep)
    # head-major pools fail the exactness condition (scheduler gate)
    bcfg = _cfg(kv_cache_layout="bhtd")
    assert not can_speculate(bcfg)
    bparams = M.init_params(KEY, bcfg)
    with pytest.raises(ValueError, match="speculat"):
        ContinuousBatchingEngine(bcfg, bparams, max_slots=2, max_len=32,
                                 spec_k=2)


def test_preemption_during_speculation(setup):
    """An injected OOM (all free pages hidden for one iteration) lands
    while speculative windows are in flight: the engine must preempt a
    resident mid-speculation, requeue, resume — and every request still
    finishes bit-identical.  ``step`` here counts engine iterations
    (windows), and the generation is long enough that the residents'
    re-ensure after the hide genuinely comes up short — a short run
    would be absorbed by admission gating without preempting anyone."""
    cfg, params = setup
    _, uids_p, out_p = _run(cfg, params, kv_mode="paged", max_new=16)
    eng, uids_s, out_s = _run(cfg, params, kv_mode="paged", spec_k=4,
                              max_new=16,
                              faults=[Fault("oom", step=2, pages=0),
                                      Fault("oom", step=4, pages=0)])
    _assert_identical(out_p, uids_p, out_s, uids_s)
    st = out_s["stats"]
    assert st.requests_completed == len(LENS)
    assert int(out_s["metrics"].value("faults_injected_total")) == 2
    assert st.preemptions >= 1
    assert eng.allocator.free_pages == eng.allocator.num_pages


# ---------------------------------------------------------------------------
# 2. Rollback invariants: tentative-commit never leaks pages
# ---------------------------------------------------------------------------

def _check_allocator_invariants(alloc):
    chains = alloc._chains
    held = [p for c in chains.values() for p in c]
    # conservation + no double-booking (free list and chains disjoint)
    assert alloc.free_pages + len(held) == alloc.num_pages
    assert len(set(held)) == len(held)
    assert not set(held) & set(alloc._free)
    for slot, chain in chains.items():
        # block table mirrors the chain, zeroed beyond it (page id 0 is
        # a real page, but trim/release zero exactly the freed columns)
        assert list(alloc.block_table[slot, :len(chain)]) == chain
        assert not alloc.block_table[slot, len(chain):].any()
        assert alloc.capacity(slot) >= int(alloc.fill[slot])


def test_engine_rollback_invariants(setup):
    """Partial-acceptance paged run with a trim spy: after EVERY
    speculative rollback the slot's chain is tight
    (len(chain) == pages_for(fill)) and the pool conserves pages."""
    cfg, params = setup
    eng = ContinuousBatchingEngine(cfg, params, max_slots=3, max_len=48,
                                   kv_mode="paged", spec_k=4,
                                   draft_keep=0.5)
    alloc = eng.allocator
    orig_trim, calls = alloc.trim, []

    def spying_trim(slot):
        freed = orig_trim(slot)
        calls.append((slot, freed))
        assert len(alloc._chains[slot]) == \
            alloc.pages_for(int(alloc.fill[slot]))
        _check_allocator_invariants(alloc)
        return freed

    alloc.trim = spying_trim
    rng = np.random.default_rng(0)
    for l in LENS:
        eng.submit(rng.integers(0, cfg.vocab_size, (l,), dtype=np.int32),
                   max_new_tokens=MAX_NEW)
    out = eng.run(KEY)
    assert calls, "no trim calls — speculative windows never rolled back?"
    assert out["stats"].requests_completed == len(LENS)
    # end state: every page home, every slot empty
    assert alloc.free_pages == alloc.num_pages
    assert not alloc.fill.any()
    assert not alloc.block_table.any()


def test_spec_kv_accounting_matches_plain(setup):
    """Speculation must not change WHAT is stored, only when: the
    committed KV accounting (live compact writes + per-layer-dense
    baseline) of a speculative paged run equals a never-speculated
    run's, because the emitted chains — hence the gates, hence the
    fresh/reuse split — are identical."""
    cfg, params = setup
    eng_p, _, _ = _run(cfg, params, kv_mode="paged")
    eng_s, _, _ = _run(cfg, params, kv_mode="paged", spec_k=4)
    sp, ss = eng_p.allocator.stats, eng_s.allocator.stats
    assert ss.entries_appended == sp.entries_appended
    assert ss.entries_dense == sp.entries_dense


def _drive_window_protocol(num_pages, page_size, n_attn, windows):
    """Replay the engine's per-window allocator protocol (ensure →
    commit appends → trim) from an abstract script and check invariants
    after every mutation.  ``windows`` is a list of per-slot
    ``(gamma, emitted, fresh_fracs)`` tuples; a slot whose reservation
    fails is evicted (the engine's preemption backpressure)."""
    cap = num_pages * page_size
    alloc = PageAllocator(num_pages, page_size, max_slots=len(windows[0]),
                          slot_entry_capacity=cap)
    live = set(range(len(windows[0])))
    for win in windows:
        for slot, (gamma, emitted, fracs) in enumerate(win):
            if slot not in live:
                continue
            need = int(alloc.fill[slot]) + (gamma + 1) * n_attn
            if need > cap or not alloc.ensure(slot, need):
                alloc.release(slot)       # preempt-youngest backpressure
                live.discard(slot)
                _check_allocator_invariants(alloc)
                continue
            _check_allocator_invariants(alloc)
            for i in range(min(emitted, gamma + 1)):
                fresh = 1 + int(round(fracs[i] * (n_attn - 1)))
                alloc.append(slot, fresh, n_attn)
            alloc.trim(slot)
            assert len(alloc._chains[slot]) == \
                alloc.pages_for(int(alloc.fill[slot]))
            _check_allocator_invariants(alloc)
    for slot in list(live):
        alloc.release(slot)
    assert alloc.free_pages == alloc.num_pages
    assert not alloc.fill.any()


def test_window_protocol_fixed_cases():
    """Deterministic mirror of the Hypothesis fuzz below — always runs,
    even where Hypothesis is not installed."""
    rng = np.random.default_rng(3)
    for num_pages, page_size, slots, n_attn in [(8, 4, 2, 3), (16, 2, 3, 4),
                                                (4, 8, 1, 2), (32, 1, 4, 3)]:
        windows = [[(int(rng.integers(0, 5)), int(rng.integers(0, 6)),
                     rng.random(6).tolist())
                    for _ in range(slots)] for _ in range(12)]
        _drive_window_protocol(num_pages, page_size, n_attn, windows)


def test_trim_is_idempotent_and_release_after_trim():
    alloc = PageAllocator(8, 2, max_slots=1, slot_entry_capacity=16)
    assert alloc.ensure(0, 10)            # 5 pages reserved
    alloc.append(0, 3, 3)                 # fill 3 -> needs 2 pages
    assert alloc.trim(0) == 3
    assert alloc.trim(0) == 0             # idempotent
    assert alloc.free_pages == 6
    assert alloc.release(0) == 2
    assert alloc.free_pages == 8


# Hypothesis fuzz — CI always has it (requirements-dev.txt), local runs
# without it still execute everything above plus the fixed-case mirrors
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                                     # pragma: no cover
    HAS_HYPOTHESIS = False

if HAS_HYPOTHESIS:
    SET = dict(max_examples=50, deadline=None)

    @given(num_pages=st.integers(2, 24), page_size=st.integers(1, 8),
           slots=st.integers(1, 4), n_attn=st.integers(1, 4),
           script=st.lists(st.tuples(st.integers(0, 4), st.integers(0, 5),
                                     st.lists(st.floats(0, 1), min_size=6,
                                              max_size=6)),
                           min_size=1, max_size=40))
    @settings(**SET)
    def test_window_protocol_property(num_pages, page_size, slots, n_attn,
                                      script):
        """Any interleaving of speculative windows across slots conserves
        pages, keeps chains tight after trim, and drains to an empty
        pool."""
        per_slot = [script[i::slots] for i in range(slots)]
        n_win = max(len(p) for p in per_slot)
        windows = [[per_slot[s][w % max(len(per_slot[s]), 1)]
                    if per_slot[s] else (0, 0, [0.0] * 6)
                    for s in range(slots)] for w in range(n_win)]
        _drive_window_protocol(num_pages, page_size, n_attn, windows)


# ---------------------------------------------------------------------------
# 3. Distribution oracle: accept/resample == sampling from the target
# ---------------------------------------------------------------------------

def _dirichletish(rng, n, V):
    p = rng.random((n, V)) ** 3 + 1e-9
    return p / p.sum(-1, keepdims=True)


def test_emitted_distribution_is_target_fixed():
    rng = np.random.default_rng(0)
    for V in (2, 7, 33):
        p_d = _dirichletish(rng, 5, V)
        p_t = _dirichletish(rng, 5, V)
        np.testing.assert_allclose(
            sampling.emitted_distribution(p_d, p_t), p_t, atol=1e-12)


def test_residual_distribution_properties():
    rng = np.random.default_rng(1)
    p_d = _dirichletish(rng, 4, 9)
    p_t = _dirichletish(rng, 4, 9)
    res = sampling.residual_distribution(p_d, p_t)
    assert (res >= 0.0).all()
    np.testing.assert_allclose(res.sum(-1), 1.0, atol=1e-12)
    assert not res[p_d >= p_t].any()      # zero where draft over-covers
    # degenerate limit: identical distributions fall back to the target
    np.testing.assert_allclose(sampling.residual_distribution(p_t, p_t),
                               p_t, atol=1e-12)


def test_greedy_verify_cases():
    tgt = np.array([[3, 5, 7, 9], [3, 5, 7, 9], [1, 1, 1, 1]])
    drf = np.array([[3, 5, 7], [3, 4, 7], [0, 1, 1]])
    acc, cor = sampling.greedy_verify(tgt, drf)
    np.testing.assert_array_equal(acc, [3, 1, 0])
    # correction comes from the column AFTER the accepted prefix
    np.testing.assert_array_equal(cor, [9, 5, 1])


def test_accept_window_all_accept_and_reject():
    V = 6
    p = np.full((4, V), 1.0 / V)
    drafts = np.array([2, 4, 1])
    # identical dists, u below the (==1) ratio: all accepted + bonus
    a, emitted = sampling.speculative_accept_window(
        drafts, p[:3], p, np.zeros(3), np.full(4, 0.99))
    assert a == 3 and emitted[:3] == [2, 4, 1]
    assert emitted[3] == sampling.inverse_cdf_sample(p[3], 0.99)
    # target puts zero mass on the first draft: immediate rejection,
    # resample from the residual
    p_t = p.copy()
    p_t[0, 2] = 0.0
    p_t[0] /= p_t[0].sum()
    a, emitted = sampling.speculative_accept_window(
        drafts, p[:3], p_t, np.zeros(3), np.full(4, 0.5))
    res = sampling.residual_distribution(p[0], p_t[0])
    assert a == 0 and len(emitted) == 1
    assert emitted[0] == sampling.inverse_cdf_sample(res, 0.5)
    assert emitted[0] != 2


def test_inverse_cdf_sample_semantics():
    p = np.array([0.25, 0.0, 0.5, 0.25])
    cdf = np.cumsum(p)
    for u in (0.0, 0.2, 0.25, 0.5, 0.74, 0.999):
        i = sampling.inverse_cdf_sample(p, u)
        assert cdf[i] > u or i == len(p) - 1
        assert i == 0 or cdf[i - 1] <= u
        assert p[i] > 0.0


if HAS_HYPOTHESIS:
    @given(data=st.data(), V=st.integers(2, 12), k=st.integers(1, 6))
    @settings(**SET)
    def test_accept_window_invariants_fuzz(data, V, k):
        """Fuzzed window: whatever the distributions and uniforms, the
        emitted prefix matches the accepted drafts, exactly one extra
        token follows, and every emitted token has positive target
        mass."""
        fl = st.floats(0.01, 1.0, allow_nan=False)
        p_d = np.array(data.draw(
            st.lists(st.lists(fl, min_size=V, max_size=V),
                     min_size=k, max_size=k)), np.float64)
        p_t = np.array(data.draw(
            st.lists(st.lists(fl, min_size=V, max_size=V),
                     min_size=k + 1, max_size=k + 1)), np.float64)
        p_d /= p_d.sum(-1, keepdims=True)
        p_t /= p_t.sum(-1, keepdims=True)
        drafts = np.array(data.draw(st.lists(st.integers(0, V - 1),
                                             min_size=k, max_size=k)))
        u01 = st.floats(0.0, 1.0, exclude_max=True, allow_nan=False)
        u_acc = np.array(data.draw(st.lists(u01, min_size=k, max_size=k)))
        u_fin = np.array(data.draw(st.lists(u01, min_size=k + 1,
                                            max_size=k + 1)))
        a, emitted = sampling.speculative_accept_window(drafts, p_d, p_t,
                                                        u_acc, u_fin)
        assert 0 <= a <= k
        assert len(emitted) == a + 1
        assert emitted[:a] == list(drafts[:a])
        for j, tok in enumerate(emitted):
            assert p_t[j, tok] > 0.0
        # the analytic marginal identity that makes all of this correct
        np.testing.assert_allclose(
            sampling.emitted_distribution(p_d, p_t[:k]), p_t[:k],
            atol=1e-9)

    @given(data=st.data(), V=st.integers(2, 16))
    @settings(**SET)
    def test_emitted_distribution_is_target_fuzz(data, V):
        fl = st.floats(0.0, 1.0, allow_nan=False)
        raw_d = np.array(data.draw(st.lists(fl, min_size=V, max_size=V)))
        raw_t = np.array(data.draw(st.lists(fl, min_size=V, max_size=V)))
        p_d = (raw_d + 1e-9) / (raw_d + 1e-9).sum()
        p_t = (raw_t + 1e-9) / (raw_t + 1e-9).sum()
        np.testing.assert_allclose(
            sampling.emitted_distribution(p_d[None], p_t[None])[0], p_t,
            atol=1e-12)
