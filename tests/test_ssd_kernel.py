"""SSD Pallas kernel vs the jnp chunk-scan (models/ssm.ssd_scan) and the
naive recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.models import ssm

KEY = jax.random.PRNGKey(0)


def _inputs(B, T, H, P, N, seed=0):
    rng = np.random.default_rng(seed)
    xh = rng.standard_normal((B, T, H, P)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, (B, T, H)).astype(np.float32)
    A_log = np.log(rng.uniform(0.5, 4.0, (H,))).astype(np.float32)
    Bm = rng.standard_normal((B, T, H, N)).astype(np.float32)
    Cm = rng.standard_normal((B, T, H, N)).astype(np.float32)
    return map(jnp.asarray, (xh, dt, A_log, Bm, Cm))


@pytest.mark.parametrize("B,T,H,P,N,Q", [
    (1, 16, 2, 4, 8, 8),
    (2, 24, 3, 8, 4, 8),
    (1, 32, 1, 16, 16, 16),
    (1, 10, 2, 4, 4, 16),         # T < chunk and not divisible
])
def test_ssd_kernel_matches_jnp_scan(B, T, H, P, N, Q):
    xh, dt, A_log, Bm, Cm = _inputs(B, T, H, P, N)
    y_k = ops.ssd_scan(xh, dt, A_log, Bm, Cm, Q)
    y_ref, _ = ssm.ssd_scan(xh, dt, A_log, Bm, Cm, Q)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_state_carry_across_chunks():
    """Output at late chunks depends on early-chunk inputs only through the
    carried state — zeroing early inputs must change late outputs."""
    xh, dt, A_log, Bm, Cm = _inputs(1, 32, 1, 4, 4, seed=3)
    y1 = ops.ssd_scan(xh, dt, A_log, Bm, Cm, 8)
    xh0 = xh.at[:, :8].set(0.0)
    y2 = ops.ssd_scan(xh0, dt, A_log, Bm, Cm, 8)
    assert float(jnp.abs(y1[:, 16:] - y2[:, 16:]).max()) > 1e-5
