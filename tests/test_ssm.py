"""Mamba-2 SSD: the chunked scan must match the naive per-token recurrence,
and the decode step must continue the scan exactly."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ssm


def naive_ssd(xh, dt, A_log, Bm, Cm):
    """Per-token linear recurrence oracle: h ← h·exp(dt·A) + dt·B⊗x."""
    B, T, H, P = xh.shape
    N = Bm.shape[-1]
    A = -np.exp(np.asarray(A_log, np.float64))
    h = np.zeros((B, H, P, N), np.float64)
    ys = np.zeros((B, T, H, P), np.float64)
    x64 = np.asarray(xh, np.float64)
    dt64 = np.asarray(dt, np.float64)
    B64 = np.asarray(Bm, np.float64)
    C64 = np.asarray(Cm, np.float64)
    for t in range(T):
        dA = np.exp(dt64[:, t] * A)                       # [B,H]
        upd = np.einsum("bhp,bhn->bhpn", x64[:, t] * dt64[:, t][..., None],
                        B64[:, t])
        h = h * dA[:, :, None, None] + upd
        ys[:, t] = np.einsum("bhn,bhpn->bhp", C64[:, t], h)
    return ys, h


@pytest.mark.parametrize("T,chunk", [(16, 4), (24, 8), (7, 16), (32, 32)])
def test_ssd_scan_matches_recurrence(T, chunk):
    rng = np.random.default_rng(0)
    B, H, P, N = 2, 3, 4, 5
    xh = rng.standard_normal((B, T, H, P)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, (B, T, H)).astype(np.float32)
    A_log = np.log(rng.uniform(0.5, 4.0, (H,))).astype(np.float32)
    Bm = rng.standard_normal((B, T, H, N)).astype(np.float32)
    Cm = rng.standard_normal((B, T, H, N)).astype(np.float32)
    y, state = ssm.ssd_scan(jnp.asarray(xh), jnp.asarray(dt),
                            jnp.asarray(A_log), jnp.asarray(Bm),
                            jnp.asarray(Cm), chunk)
    y_ref, h_ref = naive_ssd(xh, dt, A_log, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), h_ref, rtol=2e-4, atol=2e-4)


def test_ssd_masked_tokens_do_not_update_state():
    rng = np.random.default_rng(1)
    B, T, H, P, N = 1, 10, 2, 3, 4
    xh = rng.standard_normal((B, T, H, P)).astype(np.float32)
    dt = rng.uniform(0.05, 0.2, (B, T, H)).astype(np.float32)
    A_log = np.zeros((H,), np.float32)
    Bm = rng.standard_normal((B, T, H, N)).astype(np.float32)
    Cm = rng.standard_normal((B, T, H, N)).astype(np.float32)
    mask = np.ones((B, T, 1), np.float32)
    mask[:, [2, 5, 6]] = 0.0                     # skipped tokens
    dt_m = dt * mask
    _, state_masked = ssm.ssd_scan(jnp.asarray(xh), jnp.asarray(dt_m),
                                   jnp.asarray(A_log), jnp.asarray(Bm),
                                   jnp.asarray(Cm), 4)
    keep = mask[0, :, 0].astype(bool)
    _, state_dropped = ssm.ssd_scan(jnp.asarray(xh[:, keep]),
                                    jnp.asarray(dt[:, keep]),
                                    jnp.asarray(A_log),
                                    jnp.asarray(Bm[:, keep]),
                                    jnp.asarray(Cm[:, keep]), 4)
    np.testing.assert_allclose(np.asarray(state_masked),
                               np.asarray(state_dropped), rtol=1e-4,
                               atol=1e-5)


def test_ssm_step_continues_apply():
    cfg = get_config("mamba2-2.7b").smoke()
    key = jax.random.PRNGKey(0)
    p = ssm.ssm_init(key, cfg)
    B, T = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y_full, _ = ssm.ssm_apply(p, x, cfg)
    y_pre, (conv_st, ssm_st) = ssm.ssm_apply(p, x[:, :T - 1], cfg)
    y_step, _ = ssm.ssm_step(p, x[:, T - 1:], cfg, conv_st, ssm_st)
    np.testing.assert_allclose(
        np.asarray(y_step[:, 0], np.float32),
        np.asarray(y_full[:, -1], np.float32), rtol=0.1, atol=0.05)
