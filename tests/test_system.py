"""End-to-end behaviour: training reduces loss, checkpoint-resume is
bitwise-exact, serving generates with routing + KV reuse, straggler/
preemption hooks function."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.train.fault_tolerance import (ElasticPlan, PreemptionGuard,
                                         StragglerMonitor)
from repro.train.trainer import Trainer, TrainerConfig


def _tiny_cfg():
    cfg = get_config("qwen3-8b").smoke()
    return dataclasses.replace(cfg, num_layers=2, d_ff=128)


@pytest.mark.slow
def test_training_reduces_loss(tmp_path):
    cfg = _tiny_cfg()
    tcfg = TrainerConfig(seq_len=64, global_batch=4, steps=40, lr=1e-3,
                        log_every=5, ckpt_dir=None)
    tr = Trainer(cfg, tcfg)
    tr.run()
    losses = [m["loss"] for m in tr.metrics_log]
    assert losses[-1] < losses[0] - 0.2, losses


@pytest.mark.slow
def test_checkpoint_resume_bitwise(tmp_path):
    cfg = _tiny_cfg()
    common = dict(seq_len=32, global_batch=2, lr=1e-3, log_every=1,
                  ckpt_every=5)
    # run A: 10 straight steps
    trA = Trainer(cfg, TrainerConfig(steps=10, ckpt_dir=None, **common))
    stateA = trA.run()
    # run B: 5 steps, checkpoint, fresh trainer resumes to 10
    ckpt = str(tmp_path / "ck")
    trB1 = Trainer(cfg, TrainerConfig(steps=5, ckpt_dir=ckpt, **common))
    trB1.run()
    trB2 = Trainer(cfg, TrainerConfig(steps=10, ckpt_dir=ckpt, **common))
    stateB = trB2.run(resume=True)
    la = jax.tree_util.tree_leaves(stateA["params"])
    lb = jax.tree_util.tree_leaves(stateB["params"])
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


@pytest.mark.slow
def test_serve_engine_generates():
    from repro.core.routing import neutral_router_bias

    cfg = get_config("llama2-7b").smoke()
    params = neutral_router_bias(M.init_params(jax.random.PRNGKey(0), cfg))
    eng = ServeEngine(cfg, params, max_len=48)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 16),
                                                dtype=np.int32)
    out = eng.generate(prompts, 8)
    assert out["tokens"].shape == (2, 8)
    s = out["stats"]
    assert s.decode_tokens == 16
    # measured (gate-logged) saving sits in the paper's claim regime
    assert 0.0 < s.kv_saved_fraction < 0.5
    assert 0.0 < s.kv_saved_analytic < 0.5
    # greedy decoding is deterministic
    out2 = ServeEngine(cfg, params, max_len=48).generate(prompts, 8)
    np.testing.assert_array_equal(out["tokens"], out2["tokens"])


def test_serve_engine_decode_token_count_stops_at_max_len():
    """decode_tokens counts tokens actually emitted, not B*max_new."""
    cfg = get_config("llama2-7b").smoke()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_len=20)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 16),
                                                dtype=np.int32)
    out = eng.generate(prompts, 8)                # loop stops at max_len=20
    # positions 16..19 decodable -> 5 emitted per row (incl. prefill token)
    assert out["stats"].decode_tokens == 2 * 5


def test_serve_gather_mode_runs():
    cfg = get_config("llama2-7b").smoke()
    cfg = dataclasses.replace(
        cfg, skip=dataclasses.replace(cfg.skip, mode="gather"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_len=40)
    prompts = np.random.default_rng(1).integers(0, cfg.vocab_size, (1, 32),
                                                dtype=np.int32)
    out = eng.generate(prompts, 4)
    assert np.isfinite(out["tokens"]).all()


def test_straggler_monitor():
    mon = StragglerMonitor(factor=2.0, budget=2)
    for _ in range(10):
        mon.observe(1.0)
    assert not mon.reconfigure_requested
    mon.observe(5.0)
    mon.observe(5.0)
    assert mon.strikes == 2 and mon.reconfigure_requested


def test_preemption_guard_checkpoints_early(tmp_path):
    cfg = _tiny_cfg()
    tcfg = TrainerConfig(seq_len=32, global_batch=2, steps=100,
                         ckpt_dir=str(tmp_path / "ck"), ckpt_every=1000,
                         log_every=1000)
    tr = Trainer(cfg, tcfg)

    # inject preemption after 3 steps via the dataset hook
    orig = tr.dataset.batch
    calls = {"n": 0}

    def hooked(step):
        calls["n"] += 1
        if calls["n"] == 3:
            os.kill(os.getpid(), __import__("signal").SIGTERM)
        return orig(step)

    tr.dataset.batch = hooked
    state = tr.run()
    from repro.train import checkpoint as ck
    assert ck.latest_step(str(tmp_path / "ck")) == int(state["data_step"])
    assert int(state["data_step"]) < 100


def test_elastic_plan():
    plan = ElasticPlan(model=16)
    assert plan.mesh_for(256) == (16, 16)
    assert plan.mesh_for(240) == (8, 16)          # lost a host: shrink data
    assert plan.mesh_for(512) == (32, 16)
    parts = plan.host_partition(256, 8)
    assert parts[0] == (0, 32) and parts[-1] == (224, 256)
