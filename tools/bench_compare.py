"""Diff fresh benchmark artifacts against the committed baselines.

  python tools/bench_compare.py --fresh bench-results \
      [--baseline benchmarks/baselines] [--tolerance 0.10]

Every ``BENCH_<suite>.json`` the bench harness writes carries, besides
wall-clock rows (noisy, machine-dependent — never gated), the roofline /
accounting numbers under ``meta``.  This tool gates the *deterministic*
subset: byte models, saved fractions, hit rates.  A gated metric that
regresses by more than ``--tolerance`` (relative, in its bad direction)
fails the run; so does a gated metric or suite file that disappeared —
silent metric loss is itself a regression.  Improvements beyond the
tolerance are reported (so the baseline can be re-pinned) but pass.

A second, stricter class of gates — ``FLOORS`` — checks the *fresh*
artifact against an absolute bound, independent of the baseline.  These
exist for claims the repo must keep true on every machine, not merely
"no worse than last time": the continuous-vs-lockstep goodput ratio with
the fused decode loop on (>= 1.1), and the tracing-overhead guard
(traced goodput >= 0.97x untraced).  Speedup ratios are same-machine
quotients, so they travel across hosts where raw wall-clock rows do not.

This is the consumer of the perf-trajectory artifacts bench-smoke has
been uploading since PR 3: the baselines under ``benchmarks/baselines/``
are a committed snapshot of ``benchmarks.run --quick``; refresh them with

  PYTHONPATH=src python -m benchmarks.run --quick \
      --out-dir benchmarks/baselines
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Any, List

# Gated metrics: suite -> [(dotted path into the JSON, higher_is_better)].
# Paths support dict keys only ("a.b.c").  Only deterministic accounting
# goes here — wall-clock rows vary across machines and are never gated.
GATED = {
    "fused_linear": [
        # Alg.-1 fusion win: modeled activation/total HBM byte drops
        ("meta.reports.llama2-7b.activation_bytes_drop_frac", True),
        ("meta.reports.llama2-7b.total_bytes_drop_frac", True),
        ("meta.reports.llama2-7b/int4.activation_bytes_drop_frac", True),
        ("meta.reports.llama2-7b/int4.total_bytes_drop_frac", True),
        ("meta.reports.qwen3-8b.activation_bytes_drop_frac", True),
        # tensor-parallel per-chip totals: lower is better, and the tp8
        # point is the sharded-serving headline (~1/TP)
        ("meta.tp_sweep.llama2-7b.per_chip.8.total_bytes", False),
        ("meta.tp_sweep.llama2-7b.per_chip.8.total_vs_tp1", False),
    ],
    "kv_storage_25pct": [
        ("meta.saved_fraction", True),
    ],
    "paged_kv": [
        ("meta.live_entry_saving", True),
        ("meta.peak_kv_vs_dense", False),
        ("meta.history_hit_rate", True),
    ],
    "fig9_bandwidth": [
        ("meta.eff_frac.invariance_buffer", True),
        ("meta.eff_frac.paged_history", True),
        ("meta.history_hit_rate", True),
    ],
    "chunked_prefill": [
        ("meta.interleaved_steps", True),
    ],
}

# Absolute floors: suite -> [(dotted path, minimum value)].  Checked on
# the FRESH artifact only — these are invariants of the implementation
# (same-machine ratios), not snapshots to drift from.  A missing metric
# fails, same as GATED.
FLOORS = {
    "serve_continuous": [
        # PR-6 headline: the fused N-step continuous engine must beat the
        # lock-step engine on useful-token goodput by >= 1.1x
        ("meta.goodput.speedup", 1.1),
    ],
    "observability": [
        # tracing must cost < 3% goodput: traced/untraced same-machine
        # ratio (PR-7 overhead guard; see benchmarks/bench_observability)
        ("meta.overhead.traced_goodput_ratio", 0.97),
    ],
    "fault_tolerance": [
        # PR-8 robustness guard: a storm of injected faults (dispatch
        # error, OOM, stall) must keep >= 0.85x clean goodput AND the
        # survivors' tokens bit-identical (bool floor: 1 = True)
        ("meta.fault_storm.goodput_ratio", 0.85),
        ("meta.fault_storm.bit_identical", 1),
    ],
    "speculative": [
        # PR-9 headline: speculative decoding on acceptance-friendly
        # traffic must beat plain decoding by >= 1.2x tok/s, and must
        # NEVER buy that speed by changing tokens — temperature-0
        # identity on both KV paths is a hard bool floor
        ("meta.speculative.speedup", 1.2),
        ("meta.speculative.temp0_identical", 1),
        ("meta.speculative.paged_temp0_identical", 1),
    ],
    "paged_kv": [
        # PR-10 headlines.  Warm-prefix admission must answer in at most
        # half the cold TTFT (cold/warm >= 2x: the shared prefill really
        # is skipped, not re-run), and int8 page payloads must cut peak
        # KV bytes by >= 40% (fp16/int8 >= 1/0.6)
        ("meta.prefix.cold_over_warm_ttft", 2.0),
        ("meta.quant.fp16_over_int8_peak_bytes", 1.6667),
    ],
}


def _get(tree: Any, path: str):
    node = tree
    for part in path.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def _num(val) -> bool:
    return isinstance(val, (int, float)) and not isinstance(val, bool)


def compare(baseline_dir: pathlib.Path, fresh_dir: pathlib.Path,
            tolerance: float) -> List[str]:
    """Returns a list of failure strings (empty = gate passes)."""
    failures: List[str] = []
    baselines = sorted(baseline_dir.glob("BENCH_*.json"))
    if not baselines:
        return [f"no BENCH_*.json baselines under {baseline_dir}"]
    for suite in sorted(set(GATED)
                        - {p.stem[len("BENCH_"):] for p in baselines}):
        failures.append(f"{suite}: gated suite has no committed baseline "
                        f"under {baseline_dir}")
    for bpath in baselines:
        suite = bpath.stem[len("BENCH_"):]
        if suite not in GATED:
            continue
        fpath = fresh_dir / bpath.name
        if not fpath.exists():
            failures.append(f"{suite}: fresh artifact {fpath} missing "
                            "(suite dropped from the bench run?)")
            continue
        base = json.loads(bpath.read_text())
        fresh = json.loads(fpath.read_text())
        for path, higher in GATED[suite]:
            bval = _get(base, path)
            if not _num(bval):
                # a gated metric absent from the committed baseline means
                # the baseline was refreshed from a broken run — fail
                # rather than silently un-gating it
                failures.append(f"{suite}: gated metric {path} missing "
                                f"from baseline {bpath}")
                continue
            bval = float(bval)
            fval = _get(fresh, path)
            if not _num(fval):
                failures.append(f"{suite}: gated metric {path} missing "
                                f"from fresh artifact")
                continue
            fval = float(fval)
            denom = max(abs(bval), 1e-12)
            delta = (fval - bval) / denom
            worse = -delta if higher else delta
            arrow = ("equal" if worse == 0
                     else "better" if worse < 0 else "worse")
            line = (f"{suite}: {path} baseline={bval:.6g} "
                    f"fresh={fval:.6g} ({delta:+.1%}, {arrow})")
            if worse > tolerance:
                failures.append("REGRESSION " + line)
            else:
                print("  ok " + line)
    for suite, floors in sorted(FLOORS.items()):
        fpath = fresh_dir / f"BENCH_{suite}.json"
        if not fpath.exists():
            failures.append(f"{suite}: fresh artifact {fpath} missing "
                            "(floor-gated suite dropped from the run?)")
            continue
        fresh = json.loads(fpath.read_text())
        for path, floor in floors:
            fval = _get(fresh, path)
            if not _num(fval):
                failures.append(f"{suite}: floor-gated metric {path} "
                                "missing from fresh artifact")
                continue
            fval = float(fval)
            line = f"{suite}: {path} fresh={fval:.6g} floor={floor:g}"
            if fval < floor:
                failures.append("BELOW FLOOR " + line)
            else:
                print("  ok " + line)
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="directory with the just-produced BENCH_*.json")
    ap.add_argument("--baseline", default="benchmarks/baselines")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="max relative regression of a gated metric")
    args = ap.parse_args()
    failures = compare(pathlib.Path(args.baseline), pathlib.Path(args.fresh),
                       args.tolerance)
    for f in failures:
        print(f, file=sys.stderr)
    if failures:
        print(f"\n{len(failures)} gated metric(s) failed "
              f"(tolerance {args.tolerance:.0%}); if the change is "
              "intentional, refresh benchmarks/baselines/ in the same PR.",
              file=sys.stderr)
        return 1
    print("bench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
