"""Validate that every intra-repo markdown link resolves.

  python tools/check_docs.py [repo_root]

Scans every tracked ``*.md`` file for inline links/images
(``[text](target)``) and reference definitions (``[ref]: target``),
skips external schemes (http/https/mailto) and pure anchors, resolves
relative targets against the containing file, and exits non-zero listing
every target that does not exist.  Run by the CI ``docs-check`` job so
renames/moves cannot silently rot the documentation graph.
"""
from __future__ import annotations

import pathlib
import re
import sys
from typing import List, Tuple

# inline [text](target) — target up to the first unescaped ')'; tolerates
# image links (the preceding '!' is irrelevant to resolution)
_INLINE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
# reference-style definitions:  [ref]: target
_REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.MULTILINE)
_EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def _strip_code(text: str) -> str:
    """Drop fenced code blocks and inline code spans — link syntax inside
    them is illustrative, not a real link."""
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    return re.sub(r"`[^`\n]*`", "", text)


def targets_of(md: pathlib.Path) -> List[str]:
    text = _strip_code(md.read_text(encoding="utf-8"))
    return _INLINE.findall(text) + _REFDEF.findall(text)


def check_repo(root: pathlib.Path) -> List[Tuple[pathlib.Path, str]]:
    """Returns [(markdown file, broken target)] over every *.md under
    ``root`` (skipping dot-directories and virtualenv-ish trees)."""
    broken: List[Tuple[pathlib.Path, str]] = []
    for md in sorted(root.rglob("*.md")):
        if any(part.startswith(".") or part in ("node_modules", "venv")
               for part in md.relative_to(root).parts[:-1]):
            continue
        for target in targets_of(md):
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (root / path if path.startswith("/")
                        else md.parent / path)
            if not resolved.exists():
                broken.append((md, target))
    return broken


def main() -> int:
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    broken = check_repo(root)
    for md, target in broken:
        print(f"BROKEN {md.relative_to(root)}: ({target})")
    n_md = len(list(root.rglob("*.md")))
    print(f"checked {n_md} markdown files: "
          f"{'all links resolve' if not broken else f'{len(broken)} broken'}")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
