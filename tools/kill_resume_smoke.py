"""Kill-and-resume smoke: crash-consistency of the serve engine, end to
end through the launcher, across real process boundaries.

Three subprocess runs of ``repro.launch.serve`` on the same seeded
synthetic workload:

  1. clean     — uninterrupted run, results written to clean.json
  2. killed    — same workload with ``--kill-at N --snapshot-dir D``:
                 a SimulatedKill fires at step boundary N (after that
                 boundary's crash-consistent snapshot) and the process
                 exits with code 3
  3. resumed   — a fresh process with ``--resume --snapshot-dir D``
                 restores the newest snapshot and drains the survivors

The smoke passes iff the resumed run's per-request tokens and finish
reasons are bit-identical to the clean run's (temperature 0, greedy) —
the crash lost nothing.  Used by CI (see .github/workflows/ci.yml) and
runnable locally:

  PYTHONPATH=src python tools/kill_resume_smoke.py
"""
import argparse
import json
import pathlib
import subprocess
import sys
import tempfile


def _run(cmd, expect_rc):
    print("+", " ".join(cmd), flush=True)
    proc = subprocess.run(cmd, capture_output=True, text=True)
    sys.stdout.write(proc.stdout)
    if proc.returncode != expect_rc:
        sys.stderr.write(proc.stderr)
        raise SystemExit(f"expected exit code {expect_rc}, got "
                         f"{proc.returncode}")
    return proc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--kill-at", type=int, default=6)
    ap.add_argument("--paged-kv", action="store_true")
    args = ap.parse_args()

    base = [sys.executable, "-m", "repro.launch.serve",
            "--arch", args.arch, "--smoke", "--continuous",
            "--batch", str(args.batch),
            "--prompt-len", str(args.prompt_len),
            "--new-tokens", str(args.new_tokens)]
    if args.paged_kv:
        base += ["--paged-kv"]

    with tempfile.TemporaryDirectory() as tmp:
        tmp = pathlib.Path(tmp)
        clean_json = tmp / "clean.json"
        resumed_json = tmp / "resumed.json"
        snap_dir = tmp / "snaps"

        _run(base + ["--results-out", str(clean_json)], expect_rc=0)
        _run(base + ["--snapshot-dir", str(snap_dir),
                     "--kill-at", str(args.kill_at)], expect_rc=3)
        if not list(snap_dir.glob("serve_*")):
            raise SystemExit(f"killed run left no snapshot under "
                             f"{snap_dir}")
        _run(base + ["--snapshot-dir", str(snap_dir), "--resume",
                     "--results-out", str(resumed_json)], expect_rc=0)

        clean = json.loads(clean_json.read_text())
        resumed = json.loads(resumed_json.read_text())
        if sorted(clean) != sorted(resumed):
            raise SystemExit(f"request sets differ: clean={sorted(clean)} "
                             f"resumed={sorted(resumed)}")
        bad = [uid for uid in clean
               if clean[uid]["tokens"] != resumed[uid]["tokens"]
               or clean[uid]["finish_reason"]
               != resumed[uid]["finish_reason"]]
        if bad:
            for uid in bad:
                print(f"req {uid}: clean={clean[uid]} "
                      f"resumed={resumed[uid]}", file=sys.stderr)
            raise SystemExit(f"{len(bad)} request(s) diverged after "
                             "resume — crash consistency broken")
        print(f"kill/resume smoke PASS: {len(clean)} requests "
              f"bit-identical across the kill at boundary {args.kill_at}")


if __name__ == "__main__":
    main()
