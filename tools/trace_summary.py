#!/usr/bin/env python
"""Summarize a Chrome trace-event JSON emitted by ``repro.obs.Tracer``.

Usage:
    python tools/trace_summary.py trace.json [--top N] [--json]

Reports, from the span structure alone (no engine imports):

* engine time-in-phase breakdown — how each run-loop iteration's wall
  time splits across plan / headroom / prefill / dispatch / sync /
  bookkeep, plus the speculative phases draft / verify / rollback
  (the host-side anatomy of a step);
* top-N slowest requests by wall time (queued → finish), with their
  queued/prefill time split and decode-epoch count;
* preemption and recompile report: every ``preempt`` instant with its
  kind, and every ``compile`` instant with the step it landed in;
* robustness report: injected faults, load sheds, cancellations,
  snapshots/resumes, watchdog strikes and epoch shrinks — the lifecycle
  instants the fault-injection harness emits (docs/robustness.md);
* speculative-decoding report: per-window ``accept`` instants rolled up
  into drafted/accepted/emitted token counts and the overall acceptance
  rate (docs/speculative.md).

``--json`` prints the summary dict instead of the human table (what the
schema test and CI consume).  Exit code is non-zero on malformed traces
(unbalanced begin/end), so CI can gate on it.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

ENGINE_TID = 0


def load_events(path: str) -> List[dict]:
    """Read a trace file; accepts both the wrapped ``{"traceEvents": []}``
    object form and a bare event array."""
    with open(path) as f:
        data = json.load(f)
    events = data["traceEvents"] if isinstance(data, dict) else data
    if not isinstance(events, list):
        raise ValueError("trace is neither an event array nor an object "
                         "with a traceEvents array")
    return events


def pair_spans(events: List[dict]) -> Dict[int, List[dict]]:
    """Match ``B``/``E`` events per tid into span dicts
    ``{name, tid, ts, dur, depth}`` (LIFO pairing, as the format
    requires).  Raises ValueError on unbalanced or crossed spans."""
    spans: Dict[int, List[dict]] = defaultdict(list)
    stacks: Dict[int, List[dict]] = defaultdict(list)
    for ev in events:
        ph, tid = ev.get("ph"), ev.get("tid", 0)
        if ph == "B":
            stacks[tid].append(ev)
        elif ph == "E":
            if not stacks[tid]:
                raise ValueError(
                    f"unbalanced trace: 'E' at ts={ev.get('ts')} on tid "
                    f"{tid} with no open span")
            b = stacks[tid].pop()
            spans[tid].append({
                "name": b["name"], "tid": tid, "ts": b["ts"],
                "dur": ev["ts"] - b["ts"], "depth": len(stacks[tid]),
                "args": b.get("args", {})})
    leftover = {t: [b["name"] for b in s] for t, s in stacks.items() if s}
    if leftover:
        raise ValueError(f"unbalanced trace: unclosed spans {leftover}")
    return dict(spans)


def track_names(events: List[dict]) -> Dict[int, str]:
    return {ev["tid"]: ev["args"]["name"] for ev in events
            if ev.get("ph") == "M" and ev.get("name") == "thread_name"}


def summarize(events: List[dict], top: int = 5) -> dict:
    spans = pair_spans(events)
    names = track_names(events)

    # -- engine time-in-phase ----------------------------------------------
    eng = spans.get(ENGINE_TID, [])
    steps = [s for s in eng if s["name"] == "step"]
    phase_us: Dict[str, float] = defaultdict(float)
    for s in eng:
        if s["name"] != "step":
            phase_us[s["name"]] += s["dur"]
    step_us = sum(s["dur"] for s in steps)
    accounted = sum(d for n, d in phase_us.items() if n in
                    ("plan", "headroom", "prefill", "dispatch", "sync",
                     "bookkeep", "draft", "verify", "rollback"))
    if step_us:
        phase_us["other"] = max(0.0, step_us - accounted)

    # -- per-request lifecycles --------------------------------------------
    requests = []
    for tid, sp in spans.items():
        if tid == ENGINE_TID:
            continue
        root = [s for s in sp if s["name"] == "request"]
        if not root:
            continue
        decode = [s for s in sp if s["name"].startswith("decode[")]
        requests.append({
            "track": names.get(tid, f"tid {tid}"),
            "wall_us": root[0]["dur"],
            "queued_us": sum(s["dur"] for s in sp if s["name"] == "queued"),
            "prefill_us": sum(s["dur"] for s in sp
                              if s["name"] == "prefill"),
            "decode_epochs": len(decode),
            "decode_tokens": sum(int(s["args"].get("tokens", 0))
                                 for s in decode),
        })
    requests.sort(key=lambda r: -r["wall_us"])

    # -- instants: preemptions + recompiles --------------------------------
    preempts = [{"track": names.get(ev.get("tid", 0), "?"),
                 "ts": ev["ts"], **ev.get("args", {})}
                for ev in events
                if ev.get("ph") == "i" and ev.get("name") == "preempt"]
    compiles = [{"ts": ev["ts"], **ev.get("args", {})} for ev in events
                if ev.get("ph") == "i" and ev.get("name") == "compile"]

    # -- robustness instants (serve/faults.py lifecycle hardening) ---------
    robust_names = ("fault", "shed", "cancel", "snapshot", "resume",
                    "watchdog", "epoch_shrink")
    robustness: Dict[str, List[dict]] = {n: [] for n in robust_names}
    for ev in events:
        if ev.get("ph") == "i" and ev.get("name") in robustness:
            robustness[ev["name"]].append(
                {"ts": ev["ts"],
                 "track": names.get(ev.get("tid", 0), "engine"),
                 **ev.get("args", {})})
    finish_reasons: Dict[str, int] = defaultdict(int)
    for ev in events:
        if ev.get("ph") == "i" and ev.get("name") == "finish":
            finish_reasons[ev.get("args", {}).get("reason", "?")] += 1

    # -- speculative decoding: per-window "accept" instants ----------------
    accepts = [ev.get("args", {}) for ev in events
               if ev.get("ph") == "i" and ev.get("name") == "accept"]
    speculative = None
    if accepts:
        drafted = sum(int(a.get("drafted", 0)) for a in accepts)
        accepted = sum(int(a.get("accepted", 0)) for a in accepts)
        speculative = {
            "windows": len(accepts),
            "tokens_drafted": drafted,
            "tokens_accepted": accepted,
            "tokens_emitted": sum(int(a.get("emitted", 0))
                                  for a in accepts),
            "acceptance_rate": accepted / drafted if drafted else 0.0,
        }

    return {
        "n_events": len(events),
        "n_steps": len(steps),
        "step_wall_us": step_us,
        "phase_us": dict(sorted(phase_us.items(), key=lambda kv: -kv[1])),
        "slowest_requests": requests[:top],
        "n_requests": len(requests),
        "preemptions": preempts,
        "compiles": compiles,
        "robustness": {k: v for k, v in robustness.items() if v},
        "finish_reasons": dict(finish_reasons),
        "speculative": speculative,
    }


def _fmt_us(us: float) -> str:
    return f"{us / 1e3:10.3f} ms"


def print_summary(s: dict) -> None:
    print(f"{s['n_events']} events · {s['n_steps']} engine steps · "
          f"{s['n_requests']} requests")
    print(f"\nengine time-in-phase (total step wall "
          f"{s['step_wall_us'] / 1e3:.3f} ms):")
    for name, us in s["phase_us"].items():
        pct = 100.0 * us / s["step_wall_us"] if s["step_wall_us"] else 0.0
        print(f"  {name:<10}{_fmt_us(us)}  {pct:5.1f}%")
    print(f"\nslowest requests (of {s['n_requests']}):")
    for r in s["slowest_requests"]:
        print(f"  {r['track']:<10} wall {_fmt_us(r['wall_us'])}  queued "
              f"{_fmt_us(r['queued_us'])}  prefill "
              f"{_fmt_us(r['prefill_us'])}  "
              f"{r['decode_tokens']} tok / {r['decode_epochs']} epochs")
    print(f"\npreemptions: {len(s['preemptions'])}")
    for p in s["preemptions"]:
        print(f"  {p['track']:<10} at {_fmt_us(p['ts'])}  "
              f"kind={p.get('kind', '?')}")
    n_new = sum(int(c.get("n_new", 1)) for c in s["compiles"])
    print(f"recompiles: {n_new} new compiled variants in "
          f"{len(s['compiles'])} events")
    for c in s["compiles"]:
        print(f"  at {_fmt_us(c['ts'])}  +{c.get('n_new', 1)}")
    spec = s.get("speculative")
    if spec:
        print(f"\nspeculative: {spec['windows']} windows · "
              f"{spec['tokens_emitted']} emitted · acceptance "
              f"{spec['acceptance_rate']:.1%} "
              f"({spec['tokens_accepted']}/{spec['tokens_drafted']})")
    robust = s.get("robustness", {})
    if robust or s.get("finish_reasons"):
        counts = " · ".join(f"{k}={len(v)}" for k, v in robust.items())
        print(f"\nrobustness: {counts or 'no incidents'}")
        for kind, evs in robust.items():
            for e in evs:
                extra = {k: v for k, v in e.items()
                         if k not in ("ts", "track")}
                print(f"  {kind:<12} at {_fmt_us(e['ts'])}  "
                      f"{e['track']:<10} {extra}")
        reasons = s.get("finish_reasons", {})
        if reasons:
            print("finish reasons: " + ", ".join(
                f"{k}={v}" for k, v in sorted(reasons.items())))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument("--top", type=int, default=5,
                    help="slowest requests to show (default 5)")
    ap.add_argument("--json", action="store_true",
                    help="print the summary as JSON")
    args = ap.parse_args(argv)
    try:
        summary = summarize(load_events(args.trace), top=args.top)
    except (ValueError, KeyError) as e:
        print(f"error: malformed trace: {e}", file=sys.stderr)
        return 1
    if args.json:
        json.dump(summary, sys.stdout, indent=2)
        print()
    else:
        print_summary(summary)
    return 0


if __name__ == "__main__":
    sys.exit(main())
